//! The pluggable execution-backend trait and its two implementations.
//!
//! [`ExecBackend`] is the object-safe contract every backend satisfies:
//! the three GPU stage entry points of the paper (② Feature Projection,
//! ③ Neighbor Aggregation per subgraph, ④ Semantic Aggregation) plus
//! capability flags the session's scheduler consults before committing
//! to a plan of execution. Two backends ship in-tree:
//!
//! * [`NativeBackend`] — the Rust kernel substrate with exact counters
//!   and gather traces; thread-safe, so every [`SchedulePolicy`]
//!   (including real-thread inter-subgraph parallelism) applies.
//! * [`PjrtBackend`] — an adapter over [`crate::runtime::PjrtRuntime`]
//!   that executes AOT-compiled JAX/Pallas artifacts. Whole-model
//!   artifacts (the `*_full` entries of the manifest) are served through
//!   [`ExecBackend::run_full`]; per-stage artifacts, when lowered, are
//!   resolved by (model, dataset, stage) manifest lookup. Compiled
//!   executables are cached for the session's lifetime, so repeated
//!   runs and batches never recompile (HiHGNN's cross-run reusability
//!   argument, arXiv:2307.12765).
//!
//! [`SchedulePolicy`]: super::SchedulePolicy

use std::cell::RefCell;
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use crate::engine::stages;
use crate::graph::{HeteroGraph, NodeTypeId};
use crate::kernels::dense::{sgemm_cached, GemmBlocking, PackKey};
use crate::kernels::Ctx;
use crate::models::ModelPlan;
use crate::runtime::{ell_inputs, ArtifactEntry, CompiledArtifact, PjrtRuntime};
use crate::tensor::Tensor;
use crate::train::backward::{self, Grads, Tape};
use crate::{Error, Result};

/// Per-type projected features (stage-② output), keyed by node type id.
pub type Projected = BTreeMap<NodeTypeId, Tensor>;

/// What a backend can do — consulted by the session scheduler before it
/// commits to threads, trace-dependent analyses, or whole-model runs.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct BackendCaps {
    /// Neighbor Aggregation of different subgraphs may be driven from
    /// concurrent threads ([`ExecBackend::as_sync`] returns `Some`).
    /// When false, parallel policies still apply — subgraphs are
    /// assigned to *virtual* workers and the modeled schedule is
    /// analyzed identically — but native execution stays on one thread.
    pub parallel_na: bool,
    /// Kernel events carry gather traces for the L2 cache model
    /// (Table 3 / Fig 4 fidelity).
    pub records_traces: bool,
    /// The backend can execute a whole-model forward in one call
    /// ([`ExecBackend::run_full`] returns `Some`). The session prefers
    /// that path: the artifact's internal schedule subsumes the policy.
    pub whole_model: bool,
}

/// Object-safe execution backend: the paper's stage entry points plus
/// capability flags. See `docs/API.md` for the full contract; in short:
///
/// * stage methods must be deterministic for fixed inputs;
/// * `neighbor_aggregation` for distinct subgraphs must be independent
///   (the Fig 5c property the parallel schedules exploit);
/// * every kernel a stage executes is recorded into the provided [`Ctx`]
///   so the profiler can attribute it;
/// * `as_sync` returns `Some(self)` only if the stage entry points are
///   safe to call from multiple threads concurrently.
pub trait ExecBackend: std::fmt::Debug {
    /// Short backend name for reports (`"native"`, `"pjrt"`).
    fn name(&self) -> &'static str;

    /// Capability flags.
    fn caps(&self) -> BackendCaps;

    /// A fresh kernel-recording context configured for this backend
    /// (trace recording on/off, etc.).
    fn make_ctx(&self) -> Ctx;

    /// Stage ②: project every node type the plan touches.
    fn feature_projection(
        &self,
        ctx: &mut Ctx,
        plan: &ModelPlan,
        hg: &HeteroGraph,
    ) -> Result<Projected>;

    /// Project a single node type (used by fused FP+NA tasks). Returns
    /// `Ok(None)` when the plan has no projection weight for the type.
    fn project_type(
        &self,
        ctx: &mut Ctx,
        plan: &ModelPlan,
        hg: &HeteroGraph,
        ty: NodeTypeId,
    ) -> Result<Option<Tensor>>;

    /// Project an explicit feature matrix `x` with the type's stage-②
    /// weight — the row-sliced entry point the cache-aware serving path
    /// uses to project only cache-miss rows (`x` is a gathered subset of
    /// the type's features/embeddings, so the output row count equals
    /// `x.rows()`, not the type's node count). Returns `Ok(None)` when
    /// the plan has no projection weight for the type **or** the backend
    /// has no row-sliced path (the default); callers then fall back to
    /// projecting the whole type via [`ExecBackend::project_type`].
    fn project_features(
        &self,
        _ctx: &mut Ctx,
        _plan: &ModelPlan,
        _ty: NodeTypeId,
        _x: &Tensor,
    ) -> Result<Option<Tensor>> {
        Ok(None)
    }

    /// Stage ③ for one subgraph of the plan.
    fn neighbor_aggregation(
        &self,
        ctx: &mut Ctx,
        plan: &ModelPlan,
        subgraph: usize,
        projected: &Projected,
    ) -> Result<Tensor>;

    /// Stage ④: combine per-subgraph NA results into final embeddings.
    fn semantic_aggregation(
        &self,
        ctx: &mut Ctx,
        plan: &ModelPlan,
        na_results: &[Tensor],
    ) -> Result<Tensor>;

    /// Whole-model fast path: execute the entire forward in one call,
    /// returning `Ok(None)` when the backend has no such path for this
    /// plan. Backends with `caps().whole_model` override this.
    fn run_full(&self, _plan: &ModelPlan, _hg: &HeteroGraph) -> Result<Option<Tensor>> {
        Ok(None)
    }

    /// Training forward: run stages ②–④ saving the activations the
    /// backward stages need. Backends without a backward path (the
    /// default) report a config error; training then requires the
    /// native backend.
    fn forward_tape(&self, _ctx: &mut Ctx, _plan: &ModelPlan, _hg: &HeteroGraph) -> Result<Tape> {
        Err(Error::config("backend has no backward path"))
    }

    /// Stage-④ backward: fold `d_out` through semantic aggregation,
    /// accumulating semantic-weight gradients and returning one
    /// per-subgraph NA-output gradient.
    fn backward_semantic(
        &self,
        _ctx: &mut Ctx,
        _plan: &ModelPlan,
        _tape: &Tape,
        _d_out: &Tensor,
        _grads: &mut Grads,
    ) -> Result<Vec<Tensor>> {
        Err(Error::config("backend has no backward path"))
    }

    /// Stage-③ backward for one subgraph: grad-SpMM over the transposed
    /// sub-CSR plus attention backward, accumulating attention-weight
    /// and projected-feature gradients.
    fn backward_neighbor(
        &self,
        _ctx: &mut Ctx,
        _plan: &ModelPlan,
        _subgraph: usize,
        _tape: &Tape,
        _d_na: &Tensor,
        _grads: &mut Grads,
    ) -> Result<()> {
        Err(Error::config("backend has no backward path"))
    }

    /// Stage-② backward: projection-weight gradients as sgemm against
    /// the input features (and embedding-table gradients where the type
    /// is learned).
    fn backward_projection(
        &self,
        _ctx: &mut Ctx,
        _plan: &ModelPlan,
        _hg: &HeteroGraph,
        _grads: &mut Grads,
    ) -> Result<()> {
        Err(Error::config("backend has no backward path"))
    }

    /// Thread-safe view of this backend, used by real-thread parallel
    /// schedules. `None` (the default) makes the session fall back to
    /// virtual-worker execution for parallel policies.
    fn as_sync(&self) -> Option<&dyn SyncExecBackend> {
        None
    }
}

/// Marker trait for backends whose stage entry points may be called
/// from multiple threads concurrently.
pub trait SyncExecBackend: ExecBackend + Sync {}

/// Adapter presenting a thread-safe backend view as a plain
/// [`ExecBackend`]: the sharded executors run whole stage pipelines
/// inside worker-pool tasks, which can only capture `Sync` views, while
/// every stage executor takes `&dyn ExecBackend`. Wrapping bridges the
/// two without trait upcasting (which our MSRV predates) — the adapter
/// is itself `Sync` and delegates every entry point.
#[derive(Debug, Clone, Copy)]
pub struct SyncAsExec<'a>(pub &'a dyn SyncExecBackend);

impl ExecBackend for SyncAsExec<'_> {
    fn name(&self) -> &'static str {
        self.0.name()
    }

    fn caps(&self) -> BackendCaps {
        self.0.caps()
    }

    fn make_ctx(&self) -> Ctx {
        self.0.make_ctx()
    }

    fn feature_projection(
        &self,
        ctx: &mut Ctx,
        plan: &ModelPlan,
        hg: &HeteroGraph,
    ) -> Result<Projected> {
        self.0.feature_projection(ctx, plan, hg)
    }

    fn project_type(
        &self,
        ctx: &mut Ctx,
        plan: &ModelPlan,
        hg: &HeteroGraph,
        ty: NodeTypeId,
    ) -> Result<Option<Tensor>> {
        self.0.project_type(ctx, plan, hg, ty)
    }

    fn project_features(
        &self,
        ctx: &mut Ctx,
        plan: &ModelPlan,
        ty: NodeTypeId,
        x: &Tensor,
    ) -> Result<Option<Tensor>> {
        self.0.project_features(ctx, plan, ty, x)
    }

    fn neighbor_aggregation(
        &self,
        ctx: &mut Ctx,
        plan: &ModelPlan,
        subgraph: usize,
        projected: &Projected,
    ) -> Result<Tensor> {
        self.0.neighbor_aggregation(ctx, plan, subgraph, projected)
    }

    fn semantic_aggregation(
        &self,
        ctx: &mut Ctx,
        plan: &ModelPlan,
        na_results: &[Tensor],
    ) -> Result<Tensor> {
        self.0.semantic_aggregation(ctx, plan, na_results)
    }

    fn run_full(&self, plan: &ModelPlan, hg: &HeteroGraph) -> Result<Option<Tensor>> {
        self.0.run_full(plan, hg)
    }

    fn forward_tape(&self, ctx: &mut Ctx, plan: &ModelPlan, hg: &HeteroGraph) -> Result<Tape> {
        self.0.forward_tape(ctx, plan, hg)
    }

    fn backward_semantic(
        &self,
        ctx: &mut Ctx,
        plan: &ModelPlan,
        tape: &Tape,
        d_out: &Tensor,
        grads: &mut Grads,
    ) -> Result<Vec<Tensor>> {
        self.0.backward_semantic(ctx, plan, tape, d_out, grads)
    }

    fn backward_neighbor(
        &self,
        ctx: &mut Ctx,
        plan: &ModelPlan,
        subgraph: usize,
        tape: &Tape,
        d_na: &Tensor,
        grads: &mut Grads,
    ) -> Result<()> {
        self.0.backward_neighbor(ctx, plan, subgraph, tape, d_na, grads)
    }

    fn backward_projection(
        &self,
        ctx: &mut Ctx,
        plan: &ModelPlan,
        hg: &HeteroGraph,
        grads: &mut Grads,
    ) -> Result<()> {
        self.0.backward_projection(ctx, plan, hg, grads)
    }

    fn as_sync(&self) -> Option<&dyn SyncExecBackend> {
        Some(self.0)
    }
}

// ---------------------------------------------------------------------------
// NativeBackend
// ---------------------------------------------------------------------------

/// The native Rust kernel substrate (full profiling fidelity).
#[derive(Debug, Clone, Default)]
pub struct NativeBackend {
    /// sgemm cache-blocking parameters.
    pub blocking: GemmBlocking,
    /// Record gather traces for the L2 cache model (Table 3 / Fig 4
    /// need this; plain breakdowns skip it to save memory).
    pub record_traces: bool,
}

impl NativeBackend {
    /// Native backend without trace recording (lighter memory).
    pub fn new() -> NativeBackend {
        NativeBackend::default()
    }

    /// Enable/disable gather-trace recording.
    pub fn with_traces(mut self, record: bool) -> NativeBackend {
        self.record_traces = record;
        self
    }

    /// Override the sgemm blocking parameters.
    pub fn with_blocking(mut self, blocking: GemmBlocking) -> NativeBackend {
        self.blocking = blocking;
        self
    }
}

impl ExecBackend for NativeBackend {
    fn name(&self) -> &'static str {
        "native"
    }

    fn caps(&self) -> BackendCaps {
        BackendCaps {
            parallel_na: true,
            records_traces: self.record_traces,
            whole_model: false,
        }
    }

    fn make_ctx(&self) -> Ctx {
        Ctx { record_traces: self.record_traces, ..Default::default() }
    }

    fn feature_projection(
        &self,
        ctx: &mut Ctx,
        plan: &ModelPlan,
        hg: &HeteroGraph,
    ) -> Result<Projected> {
        stages::feature_projection(ctx, plan, hg, self.blocking)
    }

    fn project_type(
        &self,
        ctx: &mut Ctx,
        plan: &ModelPlan,
        hg: &HeteroGraph,
        ty: NodeTypeId,
    ) -> Result<Option<Tensor>> {
        match plan.weights.proj.get(&ty) {
            None => Ok(None),
            Some(w) => {
                let x = plan.weights.embed.get(&ty).unwrap_or_else(|| hg.features(ty));
                Ok(Some(sgemm_cached(ctx, x, w, PackKey::Proj(ty), self.blocking)?))
            }
        }
    }

    fn project_features(
        &self,
        ctx: &mut Ctx,
        plan: &ModelPlan,
        ty: NodeTypeId,
        x: &Tensor,
    ) -> Result<Option<Tensor>> {
        match plan.weights.proj.get(&ty) {
            None => Ok(None),
            Some(w) => Ok(Some(sgemm_cached(ctx, x, w, PackKey::Proj(ty), self.blocking)?)),
        }
    }

    fn neighbor_aggregation(
        &self,
        ctx: &mut Ctx,
        plan: &ModelPlan,
        subgraph: usize,
        projected: &Projected,
    ) -> Result<Tensor> {
        stages::neighbor_aggregation(ctx, plan, subgraph, projected, self.blocking)
    }

    fn semantic_aggregation(
        &self,
        ctx: &mut Ctx,
        plan: &ModelPlan,
        na_results: &[Tensor],
    ) -> Result<Tensor> {
        stages::semantic_aggregation(ctx, plan, na_results, self.blocking)
    }

    fn forward_tape(&self, ctx: &mut Ctx, plan: &ModelPlan, hg: &HeteroGraph) -> Result<Tape> {
        backward::forward_tape(ctx, plan, hg, self.blocking)
    }

    fn backward_semantic(
        &self,
        ctx: &mut Ctx,
        plan: &ModelPlan,
        tape: &Tape,
        d_out: &Tensor,
        grads: &mut Grads,
    ) -> Result<Vec<Tensor>> {
        backward::backward_semantic(ctx, plan, tape, d_out, grads, self.blocking)
    }

    fn backward_neighbor(
        &self,
        ctx: &mut Ctx,
        plan: &ModelPlan,
        subgraph: usize,
        tape: &Tape,
        d_na: &Tensor,
        grads: &mut Grads,
    ) -> Result<()> {
        backward::backward_neighbor(ctx, plan, subgraph, tape, d_na, grads, self.blocking)
    }

    fn backward_projection(
        &self,
        ctx: &mut Ctx,
        plan: &ModelPlan,
        hg: &HeteroGraph,
        grads: &mut Grads,
    ) -> Result<()> {
        backward::backward_projection(ctx, plan, hg, grads, self.blocking)
    }

    fn as_sync(&self) -> Option<&dyn SyncExecBackend> {
        Some(self)
    }
}

impl SyncExecBackend for NativeBackend {}

// ---------------------------------------------------------------------------
// PjrtBackend
// ---------------------------------------------------------------------------

/// Adapter over [`PjrtRuntime`]: executes AOT JAX/Pallas artifacts.
///
/// Stage entry points resolve per-stage artifacts by manifest lookup
/// `(model, dataset, stage)`; the `aot.py` pipeline currently lowers
/// whole-model artifacts only, so those calls report [`Error::NotFound`]
/// until per-stage artifacts are lowered, and the session uses the
/// [`ExecBackend::run_full`] path instead. Compiled executables are
/// cached per session.
pub struct PjrtBackend {
    rt: PjrtRuntime,
    /// Compiled artifacts by name — the session-scoped compile cache.
    cache: RefCell<BTreeMap<String, CompiledArtifact>>,
}

impl std::fmt::Debug for PjrtBackend {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("PjrtBackend")
            .field("root", &self.rt.root)
            .field("cached", &self.cache.borrow().len())
            .finish()
    }
}

impl PjrtBackend {
    /// Create a PJRT backend rooted at an artifact directory. Fails when
    /// the crate was built without the `pjrt` feature or the PJRT client
    /// cannot start.
    pub fn new(root: impl AsRef<Path>) -> Result<PjrtBackend> {
        Ok(PjrtBackend { rt: PjrtRuntime::new(root)?, cache: RefCell::new(BTreeMap::new()) })
    }

    /// The artifact directory this backend loads from.
    pub fn root(&self) -> &PathBuf {
        &self.rt.root
    }

    /// Manifest entry for `(plan.model, hg dataset, stage)`, or an error
    /// naming what was searched.
    fn find_entry(&self, plan: &ModelPlan, hg: &HeteroGraph, stage: &str) -> Result<ArtifactEntry> {
        let model = plan.model.name().to_ascii_lowercase();
        let dataset = hg.name.to_ascii_lowercase();
        let manifest = self.rt.manifest()?;
        manifest
            .entries
            .iter()
            .find(|e| e.model == model && e.dataset == dataset && e.stage == stage)
            .cloned()
            .ok_or_else(|| {
                Error::NotFound(format!(
                    "no '{stage}' artifact for model '{model}' on dataset '{dataset}' \
                     in {} (run `make artifacts`)",
                    self.rt.root.display()
                ))
            })
    }

    /// Compile (or fetch from the session cache) and use one artifact.
    fn with_artifact<R>(
        &self,
        entry: &ArtifactEntry,
        f: impl FnOnce(&CompiledArtifact) -> Result<R>,
    ) -> Result<R> {
        let mut cache = self.cache.borrow_mut();
        if !cache.contains_key(&entry.name) {
            let compiled = self.rt.compile(entry)?;
            cache.insert(entry.name.clone(), compiled);
        }
        f(&cache[&entry.name])
    }

    /// Assemble the whole-model artifact's ordered input list from the
    /// plan + graph, following the `aot.py` lowering convention:
    /// `[x_target, w_proj_target, (ell_idx, ell_mask) per subgraph,
    /// (attn_l, attn_r) per subgraph, sem_w, sem_b, sem_q]`, with the
    /// attention/semantic tail present only for attention models.
    fn full_inputs(&self, entry: &ArtifactEntry, plan: &ModelPlan, hg: &HeteroGraph) -> Result<Vec<Tensor>> {
        let p = plan.num_subgraphs();
        if entry.inputs.len() < 2 + 2 * p {
            return Err(Error::shape(format!(
                "artifact {} declares {} inputs; plan needs at least {} \
                 (x, w, 2 ELL tensors per subgraph)",
                entry.name,
                entry.inputs.len(),
                2 + 2 * p
            )));
        }
        // ELL width comes from the artifact's static shapes.
        let ell_k = entry.inputs[2].shape[1];
        let x = hg.features(plan.target).clone();
        // Artifacts are lowered per (model, dataset-SCALE, stage); the
        // manifest's dataset field does not carry the scale, so catch a
        // scale mismatch here with a message that names the cause
        // instead of failing deep inside shape validation.
        if x.shape() != (entry.inputs[0].shape[0], entry.inputs[0].shape[1]) {
            return Err(Error::shape(format!(
                "artifact {} was lowered for features {:?} but the session \
                 graph has {:?} — dataset scale mismatch (artifacts are \
                 per-scale; e.g. *_ci_* artifacts need DatasetScale::ci())",
                entry.name,
                entry.inputs[0].shape,
                x.shape()
            )));
        }
        let w = plan
            .weights
            .proj
            .get(&plan.target)
            .ok_or_else(|| Error::config("plan has no projection weight for its target type"))?
            .clone();
        let mut inputs = vec![x, w];
        for sg in &plan.subgraphs.subgraphs {
            let (idx, mask, _) = ell_inputs(&sg.adj, ell_k);
            inputs.push(idx);
            inputs.push(mask);
        }
        if plan.model.uses_attention() {
            let h = plan.config.hidden_dim;
            let s = plan.config.semantic_dim;
            for i in 0..p {
                inputs.push(Tensor::from_vec(1, h, plan.weights.attn_l[i].clone())?);
                inputs.push(Tensor::from_vec(1, h, plan.weights.attn_r[i].clone())?);
            }
            inputs.push(
                plan.weights
                    .sem_w
                    .clone()
                    .ok_or_else(|| Error::config("attention plan missing sem_w"))?,
            );
            inputs.push(Tensor::from_vec(1, s, plan.weights.sem_b.clone())?);
            inputs.push(
                plan.weights
                    .sem_q
                    .clone()
                    .ok_or_else(|| Error::config("attention plan missing sem_q"))?,
            );
        }
        Ok(inputs)
    }

    fn unsupported_stage(&self, plan: &ModelPlan, hg: &HeteroGraph, stage: &str) -> Error {
        match self.find_entry(plan, hg, stage) {
            Ok(_) => Error::Runtime(format!(
                "per-stage PJRT execution of '{stage}' is not wired up yet"
            )),
            Err(e) => e,
        }
    }
}

impl ExecBackend for PjrtBackend {
    fn name(&self) -> &'static str {
        "pjrt"
    }

    fn caps(&self) -> BackendCaps {
        BackendCaps { parallel_na: false, records_traces: false, whole_model: true }
    }

    fn make_ctx(&self) -> Ctx {
        Ctx::default()
    }

    fn feature_projection(
        &self,
        _ctx: &mut Ctx,
        plan: &ModelPlan,
        hg: &HeteroGraph,
    ) -> Result<Projected> {
        Err(self.unsupported_stage(plan, hg, "fp"))
    }

    fn project_type(
        &self,
        _ctx: &mut Ctx,
        plan: &ModelPlan,
        hg: &HeteroGraph,
        _ty: NodeTypeId,
    ) -> Result<Option<Tensor>> {
        Err(self.unsupported_stage(plan, hg, "fp"))
    }

    fn neighbor_aggregation(
        &self,
        _ctx: &mut Ctx,
        plan: &ModelPlan,
        _subgraph: usize,
        _projected: &Projected,
    ) -> Result<Tensor> {
        Err(Error::NotFound(format!(
            "no 'na' artifact for model '{}' (whole-model PJRT execution \
             is available via Session::run / run_full)",
            plan.model.name()
        )))
    }

    fn semantic_aggregation(
        &self,
        _ctx: &mut Ctx,
        plan: &ModelPlan,
        _na_results: &[Tensor],
    ) -> Result<Tensor> {
        Err(Error::NotFound(format!(
            "no 'sa' artifact for model '{}' (whole-model PJRT execution \
             is available via Session::run / run_full)",
            plan.model.name()
        )))
    }

    fn run_full(&self, plan: &ModelPlan, hg: &HeteroGraph) -> Result<Option<Tensor>> {
        let entry = self.find_entry(plan, hg, "full")?;
        let inputs = self.full_inputs(&entry, plan, hg)?;
        let refs: Vec<&Tensor> = inputs.iter().collect();
        let outputs = self.with_artifact(&entry, |art| art.execute(&refs))?;
        outputs
            .into_iter()
            .next()
            .map(Some)
            .ok_or_else(|| Error::Runtime(format!("artifact {} returned no outputs", entry.name)))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::datasets::{self, DatasetId, DatasetScale};
    use crate::models::{self, ModelConfig, ModelId};

    #[test]
    fn native_backend_caps_and_ctx() {
        let b = NativeBackend::new().with_traces(true);
        assert!(b.caps().parallel_na);
        assert!(b.caps().records_traces);
        assert!(!b.caps().whole_model);
        assert!(b.make_ctx().record_traces);
        assert!(b.as_sync().is_some());
        assert_eq!(b.name(), "native");
    }

    #[test]
    fn native_backend_stage_roundtrip() {
        let hg = datasets::build(DatasetId::Imdb, &DatasetScale::ci()).unwrap();
        let plan = models::build_plan(ModelId::Han, &hg, &ModelConfig::default()).unwrap();
        let b = NativeBackend::new();
        let mut ctx = b.make_ctx();
        let proj = b.feature_projection(&mut ctx, &plan, &hg).unwrap();
        let na0 = b.neighbor_aggregation(&mut ctx, &plan, 0, &proj).unwrap();
        let na1 = b.neighbor_aggregation(&mut ctx, &plan, 1, &proj).unwrap();
        let out = b.semantic_aggregation(&mut ctx, &plan, &[na0, na1]).unwrap();
        assert!(out.frob_norm() > 0.0);
        // whole-model path is a native no-op
        assert!(b.run_full(&plan, &hg).unwrap().is_none());
    }

    #[test]
    fn native_project_type_matches_fp() {
        let hg = datasets::build(DatasetId::Imdb, &DatasetScale::ci()).unwrap();
        let plan = models::build_plan(ModelId::Han, &hg, &ModelConfig::default()).unwrap();
        let b = NativeBackend::new();
        let mut ctx = b.make_ctx();
        let proj = b.feature_projection(&mut ctx, &plan, &hg).unwrap();
        for (&ty, expect) in &proj {
            let got = b.project_type(&mut ctx, &plan, &hg, ty).unwrap().unwrap();
            assert!(got.allclose(expect, 0.0, 0.0));
        }
        // a type with no projection weight
        let missing = hg.node_types().len() + 7;
        assert!(b.project_type(&mut ctx, &plan, &hg, missing).unwrap().is_none());
    }

    #[test]
    fn native_project_features_is_row_sliced_fp() {
        let hg = datasets::build(DatasetId::Imdb, &DatasetScale::ci()).unwrap();
        let plan = models::build_plan(ModelId::Han, &hg, &ModelConfig::default()).unwrap();
        let b = NativeBackend::new();
        let mut ctx = b.make_ctx();
        let proj = b.feature_projection(&mut ctx, &plan, &hg).unwrap();
        let (&ty, full) = proj.iter().next().unwrap();
        let rows: Vec<u32> = vec![3, 0, 7];
        let sub =
            crate::kernels::rearrange::index_select(&mut ctx, hg.features(ty), &rows).unwrap();
        let h = b.project_features(&mut ctx, &plan, ty, &sub).unwrap().unwrap();
        for (k, &r) in rows.iter().enumerate() {
            // bit-identical to the full-type projection — the property
            // the reuse cache's substitution relies on
            assert_eq!(h.row(k), full.row(r as usize));
        }
        let missing = hg.node_types().len() + 7;
        assert!(b.project_features(&mut ctx, &plan, missing, &sub).unwrap().is_none());
    }
}
