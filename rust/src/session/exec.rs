//! The session's schedule executor: one code path driving any
//! [`ExecBackend`] under any [`SchedulePolicy`].
//!
//! This subsumes what `Engine::run` (sequential) and the old
//! `Coordinator` (parallel / fused / mixing) used to implement
//! separately. Policies that spread Neighbor Aggregation over workers
//! use real threads when the backend is thread-safe
//! ([`ExecBackend::as_sync`]); otherwise the same worker assignment is
//! executed on one thread ("virtual workers") and the modeled schedule
//! analysis — the honest instrument, per DESIGN.md §4 — is identical.
//!
//! Real-thread execution — the NA worker schedule here, the sharded
//! executor's per-shard tasks, and the session's shard-affine batch
//! split — dispatches through [`crate::parallel::parallel_map`] on the
//! one process-wide worker pool, the same pool the kernels' intra-kernel
//! `parallel_for` uses. Tasks running on the pool execute their kernels
//! with nested data parallelism inlined (the pool's nesting rule), so
//! task-level and intra-kernel parallelism never multiply into
//! oversubscription. Single-stream stages (FP, SA, sequential NA) run
//! on the calling thread, where the hot kernels spread over the pool
//! internally.
//!
//! ## The cache-aware serving path
//!
//! [`execute_reuse`] is the executor behind
//! `SessionBuilder::reuse(ReuseSpec)`: it runs a
//! [`crate::sampler::SampledSubgraph`] with the session's
//! [`crate::reuse::ReuseCache`] threaded through every stage.
//!
//! * **Stage ② (FP)** gathers cache-hit projection rows (a `ReuseGather`
//!   DR kernel), batches the misses into one row-sliced projection per
//!   type ([`ExecBackend::project_features`], an `IndexSelect` gather +
//!   `sgemm` over miss rows only — valid because FP rows are
//!   seed-set-independent), and publishes the fresh rows back.
//! * **Stage ③ (NA)** runs the ordinary worker schedule over the
//!   sampler's *miss-only* sub-CSRs: cache-hit destination rows carry no
//!   edges, so per-edge kernel cost tracks misses; the cached aggregates
//!   (valid only at full-fanout coverage — see [`crate::reuse`]) are
//!   scattered over the result (`ReuseScatter`), and freshly computed
//!   fully-covered rows are published.
//! * **Stage ④ (SA)** is unchanged: its inputs are bit-identical to a
//!   cache-cold run's, because the sampler preserves the node set and
//!   cached rows are bit-identical substitutes.
//!
//! ## The sharded path
//!
//! [`execute_sharded`] is the executor behind
//! `SessionBuilder::partition(PartitionSpec)`: FP and NA run per shard
//! of a degree-balanced [`crate::partition::Partition`] on scoped
//! threads, a halo feature exchange hands foreign-owned projected rows
//! to their readers, and an owner-computes merge reassembles the global
//! NA tensors before SA — bit-identical to the monolithic forward (see
//! [`crate::partition`] for the invariant argument).
//!
//! `FusedSubgraph` executes here in its inter-subgraph-parallel shape —
//! fusing FP into per-worker NA tasks is incompatible with a shared
//! projection cache — keeping the policy's NA worker split, and the
//! returned `ScheduleReport` carries the *effective*
//! (inter-subgraph-parallel) policy rather than the requested label.
//! Whole-model backends never reach this path (the session keeps their
//! cached full-graph route).
//!
//! ## The distributed path
//!
//! [`execute_distributed`] is the third execution path (behind
//! `SessionBuilder::cluster`): the same owner-computes FP/NA/SA plan as
//! [`execute_sharded`], but each shard's compute runs on a
//! [`crate::cluster`] worker behind a message fabric — stage requests
//! and responses cross a [`crate::cluster::Transport`] as wire frames,
//! workers can die mid-wave and their shards re-place, and every merge
//! happens at the coordinator from `RowBlock` payloads that carry f32
//! rows bit-exactly. Output is bit-identical to both other paths.

use std::collections::BTreeMap;

use crate::cluster::{Cluster, Message, RowBlock};
use crate::coordinator::schedule::{self, lpt_assign, ScheduleReport};
use crate::gpumodel::GpuModel;
use crate::graph::sparse::Csr;
use crate::graph::HeteroGraph;
use crate::kernels::rearrange::index_select;
use crate::kernels::{Ctx, KernelCounters, KernelExec, KernelType};
use crate::models::ModelPlan;
use crate::partition::{Partition, Shard};
use crate::profiler::{Profile, StageId};
use crate::reuse::ReuseCache;
use crate::sampler::SampledSubgraph;
use crate::tensor::Tensor;
use crate::{Error, Result};

use super::backend::{ExecBackend, Projected, SyncExecBackend};
use super::SchedulePolicy;

/// Everything one staged execution produces.
#[derive(Debug)]
pub struct StagedRun {
    /// Final embeddings of the plan's target node type.
    pub output: Tensor,
    /// Per-subgraph Neighbor Aggregation results.
    pub na_results: Vec<Tensor>,
    /// Kernel-level profile (worker-attributed, modeled metrics attached).
    pub profile: Profile,
    /// Modeled schedule analysis.
    pub report: ScheduleReport,
}

/// Per-subgraph NA cost estimate for LPT assignment (nnz dominates every
/// NA variant).
fn na_costs(plan: &ModelPlan) -> Vec<f64> {
    plan.subgraphs
        .subgraphs
        .iter()
        .map(|sg| sg.adj.nnz() as f64 + 1.0)
        .collect()
}

/// Drain ctx events into the profile under one attribution; returns the
/// advanced wallclock cursor.
fn record_advance(
    profile: &mut Profile,
    ctx: &mut Ctx,
    stage: StageId,
    subgraph: Option<&str>,
    worker: usize,
    cursor: u64,
) -> u64 {
    let dur: u64 = ctx.events.iter().map(|e| e.wall_nanos).sum();
    profile.record_drain(&mut ctx.events, stage, subgraph, worker, cursor);
    cursor + dur
}

/// Execute `plan` on `backend` under `policy`. `scratch` is the
/// session-owned kernel context reused across runs (its event buffer's
/// allocation survives, so repeat runs skip the warm-up allocations).
pub fn execute(
    backend: &dyn ExecBackend,
    gpu: &GpuModel,
    plan: &ModelPlan,
    hg: &HeteroGraph,
    policy: SchedulePolicy,
    scratch: &mut Ctx,
) -> Result<StagedRun> {
    // a previous run that errored mid-stage may have left events behind;
    // they must not leak into this run's profile
    scratch.events.clear();
    match policy {
        SchedulePolicy::Sequential => run_sequential(backend, gpu, plan, hg, scratch),
        SchedulePolicy::InterSubgraphParallel { workers } => {
            run_scheduled(backend, gpu, plan, hg, workers.max(1), false, policy, scratch)
        }
        SchedulePolicy::BoundAwareMixing { workers } => {
            run_scheduled(backend, gpu, plan, hg, workers.max(1), true, policy, scratch)
        }
        SchedulePolicy::FusedSubgraph { workers } => {
            run_fused(backend, gpu, plan, hg, workers.max(1), policy, scratch)
        }
    }
}

/// FP + NA only (the Fig 5a/5b sweeps time NA in isolation).
pub fn run_na_only(
    backend: &dyn ExecBackend,
    gpu: &GpuModel,
    plan: &ModelPlan,
    hg: &HeteroGraph,
    scratch: &mut Ctx,
) -> Result<(Vec<Tensor>, Profile)> {
    scratch.events.clear();
    let mut profile = Profile {
        subgraph_build_nanos: plan.subgraphs.build_nanos,
        pool_threads: crate::parallel::current_threads(),
        ..Default::default()
    };
    let projected = backend.feature_projection(scratch, plan, hg)?;
    let mut cursor =
        record_advance(&mut profile, scratch, StageId::FeatureProjection, None, 0, 0);
    let mut na_results = Vec::with_capacity(plan.num_subgraphs());
    for i in 0..plan.num_subgraphs() {
        let name = plan.subgraphs.subgraphs[i].name.clone();
        let out = backend.neighbor_aggregation(scratch, plan, i, &projected)?;
        cursor = record_advance(
            &mut profile,
            scratch,
            StageId::NeighborAggregation,
            Some(name.as_str()),
            0,
            cursor,
        );
        na_results.push(out);
    }
    recycle_projected(scratch, projected);
    profile.attach_metrics(gpu);
    Ok((na_results, profile))
}

/// Serial FP → NA(sg0..sgP) → SA, single stream (the DGL execution the
/// paper profiles).
fn run_sequential(
    backend: &dyn ExecBackend,
    gpu: &GpuModel,
    plan: &ModelPlan,
    hg: &HeteroGraph,
    scratch: &mut Ctx,
) -> Result<StagedRun> {
    let mut profile = Profile {
        subgraph_build_nanos: plan.subgraphs.build_nanos,
        pool_threads: crate::parallel::current_threads(),
        ..Default::default()
    };
    let projected = backend.feature_projection(scratch, plan, hg)?;
    let mut cursor =
        record_advance(&mut profile, scratch, StageId::FeatureProjection, None, 0, 0);
    let mut na_results = Vec::with_capacity(plan.num_subgraphs());
    for i in 0..plan.num_subgraphs() {
        let name = plan.subgraphs.subgraphs[i].name.clone();
        let out = backend.neighbor_aggregation(scratch, plan, i, &projected)?;
        cursor = record_advance(
            &mut profile,
            scratch,
            StageId::NeighborAggregation,
            Some(name.as_str()),
            0,
            cursor,
        );
        na_results.push(out);
    }
    let output = backend.semantic_aggregation(scratch, plan, &na_results)?;
    let _ = record_advance(
        &mut profile,
        scratch,
        StageId::SemanticAggregation,
        None,
        0,
        cursor,
    );
    recycle_projected(scratch, projected);
    profile.attach_metrics(gpu);
    let report =
        schedule::analyze(&profile, 1, false, SchedulePolicy::Sequential, gpu);
    Ok(StagedRun { output, na_results, profile, report })
}

/// Park the finished per-type projection buffers in the scratch arena so
/// the next run or served batch checks them out instead of allocating —
/// the stage-② half of the steady-state zero-allocation contract.
fn recycle_projected(scratch: &mut Ctx, projected: Projected) {
    for h in projected.into_values() {
        scratch.arena.give(h.into_vec());
    }
}

type TaskOut = (usize, Vec<KernelExec>, Tensor);

/// The shared NA-stage dispatch: LPT-assign subgraphs across workers
/// (real threads when the backend allows), record every task's kernels
/// under its (subgraph, worker) attribution, and hand each result to
/// `post` — the hook where the cache-aware path scatters cached rows
/// and publishes fresh ones — before collecting.
fn run_na_stage(
    backend: &dyn ExecBackend,
    plan: &ModelPlan,
    projected: &Projected,
    workers: usize,
    profile: &mut Profile,
    scratch: &mut Ctx,
    mut post: impl FnMut(usize, &mut Tensor, &mut Profile, usize),
) -> Result<Vec<Tensor>> {
    let assignment = lpt_assign(&na_costs(plan), workers);
    let p = plan.num_subgraphs();
    let worker_outputs = match backend.as_sync() {
        Some(sync) if workers > 1 => {
            parallel_na(sync, plan, projected, &assignment, workers)?
        }
        _ => virtual_na(backend, plan, projected, &assignment, workers, scratch)?,
    };
    let mut task_outs: Vec<Option<TaskOut>> = (0..p).map(|_| None).collect();
    for per_worker in worker_outputs {
        for (i, events, t) in per_worker {
            task_outs[i] = Some((i, events, t));
        }
    }
    let mut na_results = Vec::with_capacity(p);
    for (i, slot) in task_outs.into_iter().enumerate() {
        let (_, events, mut t) = slot
            .ok_or_else(|| Error::config(format!("subgraph {i} was never scheduled")))?;
        profile.record(
            events,
            StageId::NeighborAggregation,
            Some(plan.subgraphs.subgraphs[i].name.as_str()),
            assignment[i],
            0,
        );
        post(i, &mut t, &mut *profile, assignment[i]);
        na_results.push(t);
    }
    Ok(na_results)
}

/// FP serial → NA across workers → barrier → SA.
#[allow(clippy::too_many_arguments)]
fn run_scheduled(
    backend: &dyn ExecBackend,
    gpu: &GpuModel,
    plan: &ModelPlan,
    hg: &HeteroGraph,
    workers: usize,
    mixing: bool,
    policy: SchedulePolicy,
    scratch: &mut Ctx,
) -> Result<StagedRun> {
    let mut profile = Profile {
        subgraph_build_nanos: plan.subgraphs.build_nanos,
        pool_threads: crate::parallel::current_threads(),
        ..Default::default()
    };

    // ② FP (single stream, worker 0)
    let projected = backend.feature_projection(scratch, plan, hg)?;
    record_advance(&mut profile, scratch, StageId::FeatureProjection, None, 0, 0);

    // ③ NA spread over workers (real threads when the backend allows)
    let na_results = run_na_stage(
        backend,
        plan,
        &projected,
        workers,
        &mut profile,
        scratch,
        |_, _, _, _| {},
    )?;

    // barrier, then ④ SA on worker 0
    let output = backend.semantic_aggregation(scratch, plan, &na_results)?;
    record_advance(&mut profile, scratch, StageId::SemanticAggregation, None, 0, 0);
    recycle_projected(scratch, projected);

    profile.attach_metrics(gpu);
    let report = schedule::analyze(&profile, workers, mixing, policy, gpu);
    Ok(StagedRun { output, na_results, profile, report })
}

/// NA worker tasks dispatched through the shared worker pool, one task
/// per worker (tasks run their kernels with nested parallelism inlined,
/// so subgraph-level and intra-kernel parallelism share the pool).
fn parallel_na(
    backend: &dyn SyncExecBackend,
    plan: &ModelPlan,
    projected: &Projected,
    assignment: &[usize],
    workers: usize,
) -> Result<Vec<Vec<TaskOut>>> {
    let p = assignment.len();
    crate::parallel::parallel_map(workers, |w| -> Result<Vec<TaskOut>> {
        let mut out = Vec::new();
        for i in (0..p).filter(|&i| assignment[i] == w) {
            let mut wctx = backend.make_ctx();
            let t = backend.neighbor_aggregation(&mut wctx, plan, i, projected)?;
            out.push((i, wctx.drain(), t));
        }
        Ok(out)
    })
    .into_iter()
    .collect()
}

/// NA tasks executed on the calling thread, attributed to their assigned
/// (virtual) workers — used for backends without a thread-safe view and
/// for single-worker schedules, where executing through the session's
/// `scratch` context keeps the arena'd NA outputs reusable across runs.
fn virtual_na(
    backend: &dyn ExecBackend,
    plan: &ModelPlan,
    projected: &Projected,
    assignment: &[usize],
    workers: usize,
    scratch: &mut Ctx,
) -> Result<Vec<Vec<TaskOut>>> {
    let p = assignment.len();
    let mut out: Vec<Vec<TaskOut>> = (0..workers).map(|_| Vec::new()).collect();
    for w in 0..workers {
        for i in (0..p).filter(|&i| assignment[i] == w) {
            let t = backend.neighbor_aggregation(scratch, plan, i, projected)?;
            out[w].push((i, scratch.drain(), t));
        }
    }
    Ok(out)
}

/// §5 guideline 2: per-subgraph fused (FP + NA) tasks.
///
/// Each worker projects the types *its* subgraphs need (first use wins
/// within a worker); types shared across workers are projected
/// redundantly — that duplication is the fusion trade-off the ablation
/// quantifies. Fused tasks attribute all their kernels (including the
/// projection sgemms) to NA: that is what fusion means for the schedule.
fn run_fused(
    backend: &dyn ExecBackend,
    gpu: &GpuModel,
    plan: &ModelPlan,
    hg: &HeteroGraph,
    workers: usize,
    policy: SchedulePolicy,
    scratch: &mut Ctx,
) -> Result<StagedRun> {
    let mut profile = Profile {
        subgraph_build_nanos: plan.subgraphs.build_nanos,
        pool_threads: crate::parallel::current_threads(),
        ..Default::default()
    };
    let assignment = lpt_assign(&na_costs(plan), workers);
    let p = plan.num_subgraphs();

    let worker_outputs = match backend.as_sync() {
        Some(sync) if workers > 1 => {
            parallel_fused(sync, plan, hg, &assignment, workers)?
        }
        _ => virtual_fused(backend, plan, hg, &assignment, workers, scratch)?,
    };

    let mut results: Vec<Option<Tensor>> = (0..p).map(|_| None).collect();
    for per_worker in worker_outputs {
        for (i, events, t) in per_worker {
            profile.record(
                events,
                StageId::NeighborAggregation,
                Some(plan.subgraphs.subgraphs[i].name.as_str()),
                assignment[i],
                0,
            );
            results[i] = Some(t);
        }
    }
    let na_results: Vec<Tensor> = results
        .into_iter()
        .enumerate()
        .map(|(i, r)| r.ok_or_else(|| Error::config(format!("subgraph {i} missing"))))
        .collect::<Result<_>>()?;

    let output = backend.semantic_aggregation(scratch, plan, &na_results)?;
    record_advance(&mut profile, scratch, StageId::SemanticAggregation, None, 0, 0);

    profile.attach_metrics(gpu);
    let report = schedule::analyze(&profile, workers, false, policy, gpu);
    Ok(StagedRun { output, na_results, profile, report })
}

/// One fused (FP+NA) task: project the subgraph's endpoint types into
/// the worker-local map if absent, then aggregate. Generic over the
/// (possibly unsized) backend so both `dyn ExecBackend` and
/// `dyn SyncExecBackend` callers work without trait upcasting.
fn fused_task<B: ExecBackend + ?Sized>(
    backend: &B,
    ctx: &mut Ctx,
    plan: &ModelPlan,
    hg: &HeteroGraph,
    local_proj: &mut Projected,
    i: usize,
) -> Result<Tensor> {
    let sg = &plan.subgraphs.subgraphs[i];
    for ty in [sg.src_type, sg.dst_type] {
        if let std::collections::btree_map::Entry::Vacant(slot) = local_proj.entry(ty) {
            if let Some(h) = backend.project_type(ctx, plan, hg, ty)? {
                slot.insert(h);
            }
        }
    }
    backend.neighbor_aggregation(ctx, plan, i, local_proj)
}

/// Fused (FP+NA) worker tasks dispatched through the shared worker
/// pool, one task per worker.
fn parallel_fused(
    backend: &dyn SyncExecBackend,
    plan: &ModelPlan,
    hg: &HeteroGraph,
    assignment: &[usize],
    workers: usize,
) -> Result<Vec<Vec<TaskOut>>> {
    let p = assignment.len();
    crate::parallel::parallel_map(workers, |w| -> Result<Vec<TaskOut>> {
        let mut out = Vec::new();
        let mut local_proj: Projected = BTreeMap::new();
        for i in (0..p).filter(|&i| assignment[i] == w) {
            let mut wctx = backend.make_ctx();
            let t = fused_task(backend, &mut wctx, plan, hg, &mut local_proj, i)?;
            out.push((i, wctx.drain(), t));
        }
        Ok(out)
    })
    .into_iter()
    .collect()
}

/// Execute a sampled batch through the reuse caches (see the module
/// docs): cache-aware FP, NA over the miss-only sub-CSRs with cached
/// aggregates scattered on top, then SA. The returned profile and
/// report carry the cache's cumulative [`crate::reuse::ReuseStats`].
pub fn execute_reuse(
    backend: &dyn ExecBackend,
    gpu: &GpuModel,
    sampled: &SampledSubgraph,
    policy: SchedulePolicy,
    scratch: &mut Ctx,
    cache: &mut ReuseCache,
) -> Result<StagedRun> {
    scratch.events.clear();
    let plan = &sampled.plan;
    let hg = &sampled.graph;
    // FusedSubgraph collapses to inter-subgraph parallel here (fusing
    // FP into per-worker NA tasks is incompatible with a shared
    // projection cache); the report must carry the policy that actually
    // executed, not the requested label
    let (workers, mixing, effective) = match policy {
        SchedulePolicy::Sequential => (1, false, policy),
        SchedulePolicy::InterSubgraphParallel { workers } => (workers.max(1), false, policy),
        SchedulePolicy::FusedSubgraph { workers } => {
            let w = workers.max(1);
            (w, false, SchedulePolicy::InterSubgraphParallel { workers: w })
        }
        SchedulePolicy::BoundAwareMixing { workers } => (workers.max(1), true, policy),
    };
    let mut profile = Profile {
        subgraph_build_nanos: plan.subgraphs.build_nanos,
        pool_threads: crate::parallel::current_threads(),
        ..Default::default()
    };

    // ② FP through the projection cache (single stream, worker 0)
    let projected =
        reuse_feature_projection(backend, scratch, plan, hg, &sampled.nodes, cache)?;
    record_advance(&mut profile, scratch, StageId::FeatureProjection, None, 0, 0);

    // ③ NA over the miss-only sub-CSRs, spread over workers; the hook
    // overlays cached aggregates and publishes this batch's fresh rows
    let na_results = run_na_stage(
        backend,
        plan,
        &projected,
        workers,
        &mut profile,
        scratch,
        |i, t, profile, worker| {
            if let Some(ov) = &sampled.overlay {
                // cache-hit rows: scatter the stored aggregates over the
                // zero rows their edge-less sub-CSR rows produced
                if let Some(exec) = scatter_rows(t, &ov.prefilled[i]) {
                    profile.record(
                        vec![exec],
                        StageId::NeighborAggregation,
                        Some(plan.subgraphs.subgraphs[i].name.as_str()),
                        worker,
                        0,
                    );
                }
                // fully-covered fresh rows: publish to the cache
                for &(l, parent) in &ov.computed[i] {
                    cache.agg_insert(i, parent, t.row(l as usize));
                }
            }
        },
    )?;

    // barrier, then ④ SA on worker 0
    let output = backend.semantic_aggregation(scratch, plan, &na_results)?;
    record_advance(&mut profile, scratch, StageId::SemanticAggregation, None, 0, 0);
    recycle_projected(scratch, projected);

    profile.attach_metrics(gpu);
    // one authoritative snapshot of the cumulative counters, carried by
    // both the profile and the schedule report
    let stats = cache.stats().clone();
    profile.reuse = Some(stats.clone());
    let mut report = schedule::analyze(&profile, workers, mixing, effective, gpu);
    report.reuse = Some(stats);
    Ok(StagedRun { output, na_results, profile, report })
}

/// Stage ② with the projection cache: gather cached rows (`ReuseGather`),
/// batch the misses into one row-sliced projection per type, publish the
/// fresh rows. Projection rows are seed-set-independent, so a row cached
/// under any earlier batch substitutes bit-identically here.
fn reuse_feature_projection(
    backend: &dyn ExecBackend,
    ctx: &mut Ctx,
    plan: &ModelPlan,
    hg: &HeteroGraph,
    nodes: &[Vec<u32>],
    cache: &mut ReuseCache,
) -> Result<Projected> {
    // skip per-row lookups entirely when the projection cache can never
    // hold a row (ReuseSpec::caps(0, n), aggregate-only reuse)
    let proj_on = cache.proj_enabled();
    let mut projected: Projected = BTreeMap::new();
    for (&ty, w) in &plan.weights.proj {
        let hidden = w.cols();
        let parents = &nodes[ty];
        // scatter target allocated lazily, on the first cache hit only —
        // all-miss (cold) and cache-disabled batches adopt the
        // projection result directly, with no zero-fill or copy
        let mut hit_rows: Option<Tensor> = None;
        let mut miss: Vec<u32> = Vec::new();
        if proj_on {
            let t0 = std::time::Instant::now();
            let mut hits = 0u64;
            for (l, &g) in parents.iter().enumerate() {
                match cache.proj_get(ty, g) {
                    Some(row) => {
                        hit_rows
                            .get_or_insert_with(|| Tensor::zeros(parents.len(), hidden))
                            .set_row(l, row);
                        hits += 1;
                    }
                    None => miss.push(l as u32),
                }
            }
            let gather_nanos = t0.elapsed().as_nanos() as u64;
            if hits > 0 {
                // read side reflects the cache's storage format (f16 and
                // int8 rows occupy 2-4x less than f32); the scatter side
                // always writes dequantized f32 rows
                let stored = hits * cache.stored_row_bytes(hidden);
                let written = hits * hidden as u64 * 4;
                ctx.push(
                    "ReuseGather",
                    KernelType::DataRearrange,
                    KernelCounters {
                        flops: 0,
                        bytes_read: stored + hits * 4,
                        bytes_written: written,
                    },
                    gather_nanos,
                    None,
                );
            }
        } else {
            miss.extend(0..parents.len() as u32);
        }
        let out = if miss.is_empty() {
            // every row hit (or the type has no sampled nodes)
            hit_rows.unwrap_or_else(|| Tensor::zeros(parents.len(), hidden))
        } else {
            // R-GCN projects learned embeddings (already sliced to the
            // sampled rows); the other models project raw features
            let x = plan.weights.embed.get(&ty).unwrap_or_else(|| hg.features(ty));
            let no_path =
                || Error::config(format!("reuse FP: type {ty} has no projection path"));
            let h_miss = if miss.len() == parents.len() {
                // every row missed (cold or disabled cache): project the
                // already-compact input directly, no gather copy
                match backend.project_features(ctx, plan, ty, x)? {
                    Some(h) => h,
                    None => backend.project_type(ctx, plan, hg, ty)?.ok_or_else(no_path)?,
                }
            } else {
                let x_miss = index_select(ctx, x, &miss)?;
                match backend.project_features(ctx, plan, ty, &x_miss)? {
                    Some(h) => h,
                    None => {
                        // no row-sliced path on this backend: project the
                        // whole type once and slice (the cache still fills)
                        let full =
                            backend.project_type(ctx, plan, hg, ty)?.ok_or_else(no_path)?;
                        index_select(ctx, &full, &miss)?
                    }
                }
            };
            if h_miss.shape() != (miss.len(), hidden) {
                return Err(Error::shape(format!(
                    "reuse FP: projected shape {:?}, expected ({}, {hidden})",
                    h_miss.shape(),
                    miss.len()
                )));
            }
            if proj_on {
                for (k, &l) in miss.iter().enumerate() {
                    cache.proj_insert(ty, parents[l as usize], h_miss.row(k));
                }
            }
            match hit_rows {
                // partial hits: scatter the fresh rows into the target
                Some(mut o) => {
                    for (k, &l) in miss.iter().enumerate() {
                        o.set_row(l as usize, h_miss.row(k));
                    }
                    o
                }
                // every row fresh: the projection IS the output
                None => h_miss,
            }
        };
        projected.insert(ty, out);
    }
    Ok(projected)
}

/// Scatter cached stage-③ rows over an NA result; returns the DR kernel
/// record when any row was written.
fn scatter_rows(t: &mut Tensor, rows: &[(u32, Vec<f32>)]) -> Option<KernelExec> {
    if rows.is_empty() {
        return None;
    }
    let t0 = std::time::Instant::now();
    for (l, row) in rows {
        t.set_row(*l as usize, row);
    }
    let nanos = t0.elapsed().as_nanos() as u64;
    let bytes: u64 = rows.iter().map(|(_, r)| r.len() as u64 * 4).sum();
    Some(KernelExec {
        name: "ReuseScatter",
        ktype: KernelType::DataRearrange,
        counters: KernelCounters {
            flops: 0,
            bytes_read: bytes + rows.len() as u64 * 4,
            bytes_written: bytes,
        },
        wall_nanos: nanos,
        trace: None,
    })
}

// ---------------------------------------------------------------------------
// Epoch-flip patch execution
// ---------------------------------------------------------------------------

/// What one epoch-flip patch execution produces.
#[derive(Debug)]
pub struct PatchRun {
    /// Refreshed full-graph output of the target type.
    pub output: Tensor,
    /// Kernel-level profile of the flip (FP + compact NA + SA only).
    pub profile: Profile,
    /// Destination rows whose NA was actually recomputed.
    pub na_rows: usize,
}

/// Incrementally refresh a full-graph forward after an epoch flip.
///
/// Stage ② re-runs in full (row-local and FP-cheap per the paper's Fig 2
/// breakdown; features or embeddings may have changed anywhere), but
/// stage ③ — the dominant stage — runs **only over the touched
/// destination rows** of each patched subgraph, on a compact sub-CSR
/// whose rows/columns are remapped to the ascending union of touched
/// destinations and their sources. Ascending remap preserves each row's
/// f32 accumulation order, and every NA variant is destination-row-local
/// (see [`crate::reuse`]), so spliced rows are bit-identical to a cold
/// full recompute. Stage ④ is globally coupled (HAN/MAGNN's β averages
/// over all target rows) and re-runs in full over the spliced tensors.
///
/// `touched` holds, per subgraph, the sorted distinct destination rows to
/// recompute (empty slices skip the subgraph entirely — no NA kernel is
/// launched for it, the property `tests/integration_dynamic.rs` asserts
/// via kernel counts). `na_cache` carries the previous epoch's full NA
/// tensors and is grown/spliced in place.
pub fn execute_patch(
    backend: &dyn ExecBackend,
    gpu: &GpuModel,
    plan: &ModelPlan,
    hg: &HeteroGraph,
    touched: &[Vec<u32>],
    na_cache: &mut Vec<Tensor>,
    scratch: &mut Ctx,
) -> Result<PatchRun> {
    scratch.events.clear();
    if touched.len() != plan.num_subgraphs() || na_cache.len() != plan.num_subgraphs() {
        return Err(Error::shape(format!(
            "patch: {} touched sets / {} cached NA tensors for {} subgraphs",
            touched.len(),
            na_cache.len(),
            plan.num_subgraphs()
        )));
    }
    let mut profile = Profile {
        subgraph_build_nanos: plan.subgraphs.build_nanos,
        pool_threads: crate::parallel::current_threads(),
        ..Default::default()
    };

    // ② full FP over the flipped graph
    let projected = backend.feature_projection(scratch, plan, hg)?;
    let mut cursor =
        record_advance(&mut profile, scratch, StageId::FeatureProjection, None, 0, 0);

    // a patch plan sharing the real weights: compact subgraphs where
    // touched, edge-less placeholders elsewhere (never aggregated, but
    // attention weight vectors are indexed by subgraph position, so the
    // index space must stay aligned)
    let mut compact: Vec<(Vec<u32>, bool)> = Vec::with_capacity(touched.len());
    let patch_subs: Vec<crate::metapath::Subgraph> = plan
        .subgraphs
        .subgraphs
        .iter()
        .zip(touched)
        .map(|(sg, dsts)| {
            let (adj, local, unified) = if dsts.is_empty() {
                (Csr::empty(0, 0), Vec::new(), false)
            } else {
                compact_patch_adj(&sg.adj, dsts, sg.src_type == sg.dst_type)
            };
            compact.push((local, unified));
            crate::metapath::Subgraph {
                metapath: sg.metapath.clone(),
                name: sg.name.clone(),
                dst_type: sg.dst_type,
                src_type: sg.src_type,
                adj,
            }
        })
        .collect();
    let patch_plan = ModelPlan {
        model: plan.model,
        config: plan.config.clone(),
        subgraphs: crate::metapath::SubgraphSet { subgraphs: patch_subs, build_nanos: 0 },
        weights: plan.weights.clone(),
        target: plan.target,
    };

    // ③ compact NA per touched subgraph, spliced over the cached tensors
    let mut na_rows = 0usize;
    for (si, dsts) in touched.iter().enumerate() {
        let sg = &plan.subgraphs.subgraphs[si];
        // grow the cached tensor first: new destination nodes appended
        // rows (always in the touched set — their rows differ from the
        // previous epoch's nonexistent ones)
        let cols = na_cache[si].cols();
        if na_cache[si].rows() < sg.adj.n_rows {
            let extra = Tensor::zeros(sg.adj.n_rows - na_cache[si].rows(), cols);
            na_cache[si] = crate::tensor::vstack(&[&na_cache[si], &extra])?;
        }
        if dsts.is_empty() {
            continue;
        }
        let (local, unified) = &compact[si];
        let psg = &patch_plan.subgraphs.subgraphs[si];
        let mut view: Projected = BTreeMap::new();
        let h_src = projected
            .get(&sg.src_type)
            .ok_or_else(|| Error::config(format!("patch: type {} not projected", sg.src_type)))?;
        view.insert(sg.src_type, index_select(scratch, h_src, local)?);
        if !*unified && sg.dst_type != sg.src_type {
            let h_dst = projected.get(&sg.dst_type).ok_or_else(|| {
                Error::config(format!("patch: type {} not projected", sg.dst_type))
            })?;
            view.insert(sg.dst_type, index_select(scratch, h_dst, dsts)?);
        }
        let out = backend.neighbor_aggregation(scratch, &patch_plan, si, &view)?;
        cursor = record_advance(
            &mut profile,
            scratch,
            StageId::NeighborAggregation,
            Some(psg.name.as_str()),
            0,
            cursor,
        );
        for &d in dsts {
            let pos = if *unified {
                local.binary_search(&d).expect("touched dst in unified space")
            } else {
                dsts.binary_search(&d).expect("touched dst in own list")
            };
            na_cache[si].set_row(d as usize, out.row(pos));
        }
        na_rows += dsts.len();
    }

    // ④ full SA over the spliced tensors
    let output = backend.semantic_aggregation(scratch, plan, na_cache)?;
    let _ = record_advance(
        &mut profile,
        scratch,
        StageId::SemanticAggregation,
        None,
        0,
        cursor,
    );
    recycle_projected(scratch, projected);
    profile.attach_metrics(gpu);
    Ok(PatchRun { output, profile, na_rows })
}

/// Build the compact patch sub-CSR for one subgraph's touched rows.
///
/// Returns `(adj, local, unified)`: when `same_type` (metapath
/// subgraphs, endpoint == start), `local` is the ascending union of
/// touched destinations and their sources, `adj` is `|local| x |local|`
/// with untouched rows edge-less (the sampler's one-local-space shape);
/// otherwise `local` is the ascending source set, `adj` is
/// `|dsts| x |local|` with rows in `dsts` order.
fn compact_patch_adj(adj: &Csr, dsts: &[u32], same_type: bool) -> (Csr, Vec<u32>, bool) {
    let mut srcs: Vec<u32> = dsts
        .iter()
        .flat_map(|&d| adj.row(d as usize).iter().copied())
        .collect();
    if same_type {
        srcs.extend_from_slice(dsts);
    }
    srcs.sort_unstable();
    srcs.dedup();
    let mut indptr: Vec<u32> = Vec::new();
    let mut indices: Vec<u32> = Vec::new();
    indptr.push(0);
    let remap = |g: u32| srcs.binary_search(&g).expect("source in local space") as u32;
    if same_type {
        for &g in &srcs {
            if dsts.binary_search(&g).is_ok() {
                indices.extend(adj.row(g as usize).iter().map(|&s| remap(s)));
            }
            indptr.push(indices.len() as u32);
        }
        let n = srcs.len();
        (Csr { n_rows: n, n_cols: n, indptr, indices }, srcs, true)
    } else {
        for &d in dsts {
            indices.extend(adj.row(d as usize).iter().map(|&s| remap(s)));
            indptr.push(indices.len() as u32);
        }
        let n_cols = srcs.len();
        (Csr { n_rows: dsts.len(), n_cols, indptr, indices }, srcs, false)
    }
}

// ---------------------------------------------------------------------------
// Sharded execution
// ---------------------------------------------------------------------------

/// Per-shard stage-② output: kernel events + (type, owned-row projection)
/// pairs.
type FpOut = (Vec<KernelExec>, Vec<(usize, Tensor)>);
/// Per-shard stage-③ output: halo-exchange events + per-subgraph
/// (events, NA result) pairs.
type NaOut = (Vec<KernelExec>, Vec<(Vec<KernelExec>, Tensor)>);

/// Execute the full-graph forward over a degree-balanced [`Partition`]
/// (see `SessionBuilder::partition`): FP and NA run **per shard** as
/// tasks on the shared worker pool (shards LPT-packed onto
/// `spec.threads` pool tasks via the canonical [`lpt_assign`]; kernel
/// parallelism inlines inside each task), with an explicit halo
/// feature-exchange step between them, then the owner-computes merge
/// reassembles the global NA tensors and SA runs once.
///
/// * **② FP, owner-computes** — each shard projects only the feature
///   rows it owns (`IndexSelect` gather + row-sliced
///   [`ExecBackend::project_features`]; backends without that entry
///   point fall back to whole-type projection + slice). A `ShardMerge`
///   DR kernel scatters the disjoint row sets into the global per-type
///   matrices.
/// * **Halo exchange** — each shard gathers its local slice (owned ∪
///   halo rows, ascending global order) from the merged matrices: owned
///   rows come from its own compute, halo rows from their owners'.
///   Recorded as a `HaloExchange` DR kernel per shard.
/// * **③ NA** — each shard aggregates its complete owned destination
///   rows over its local sub-CSRs. Because local ids ascend with global
///   ids and every owned row keeps its full neighbor list, each row's
///   f32 accumulation order is exactly the unsharded order.
/// * **Merge + ④ SA** — owned rows scatter into global NA tensors
///   (disjoint cover, one writer per row — another `ShardMerge`), and
///   Semantic Aggregation runs over them unchanged. The output is
///   **bit-identical** to the unsharded forward
///   (`tests/integration_partition.rs` pins this for RGCN/HAN/MAGNN
///   across 1/2/4 shards).
///
/// Backends without a thread-safe view ([`ExecBackend::as_sync`] =
/// `None`) execute the same shard schedule on one thread; the modeled
/// report is identical. The returned report carries the effective
/// parallel shape (`InterSubgraphParallel` at the thread count) plus the
/// partition's [`crate::partition::ShardingInfo`].
pub fn execute_sharded(
    backend: &dyn ExecBackend,
    gpu: &GpuModel,
    plan: &ModelPlan,
    hg: &HeteroGraph,
    part: &Partition,
    scratch: &mut Ctx,
) -> Result<StagedRun> {
    scratch.events.clear();
    let k = part.num_shards();
    let threads = part.spec().threads.max(1).min(k);
    let thread_of = lpt_assign(part.shard_costs(), threads);
    let mut profile = Profile {
        subgraph_build_nanos: plan.subgraphs.build_nanos,
        pool_threads: crate::parallel::current_threads(),
        ..Default::default()
    };

    // ② FP, owner-computes, spread over shard threads
    let fp_outs: Vec<FpOut> = match backend.as_sync() {
        Some(sync) if threads > 1 => {
            run_shards_parallel(k, threads, &thread_of, |s| {
                let mut ctx = sync.make_ctx();
                let rows = fp_shard_task(sync, &part.shards[s], &mut ctx, plan, hg)?;
                Ok((ctx.drain(), rows))
            })?
        }
        _ => (0..k)
            .map(|s| {
                let mut ctx = backend.make_ctx();
                let rows = fp_shard_task(backend, &part.shards[s], &mut ctx, plan, hg)?;
                Ok((ctx.drain(), rows))
            })
            .collect::<Result<Vec<_>>>()?,
    };
    let mut shard_fp: Vec<Vec<(usize, Tensor)>> = Vec::with_capacity(k);
    for (s, (events, rows)) in fp_outs.into_iter().enumerate() {
        profile.record(events, StageId::FeatureProjection, None, thread_of[s], 0);
        shard_fp.push(rows);
    }

    // barrier: scatter the disjoint owned-row projections into the
    // global per-type matrices (the stage-② merge)
    let t0 = std::time::Instant::now();
    let mut projected: Projected = BTreeMap::new();
    for (&ty, w) in &plan.weights.proj {
        let rows = plan
            .weights
            .embed
            .get(&ty)
            .map(|e| e.rows())
            .unwrap_or_else(|| hg.node_type(ty).count);
        projected.insert(ty, Tensor::zeros(rows, w.cols()));
    }
    let mut fp_bytes = 0u64;
    for (s, rows) in shard_fp.into_iter().enumerate() {
        for (ty, h) in rows {
            fp_bytes += h.bytes() as u64;
            let target = projected
                .get_mut(&ty)
                .ok_or_else(|| Error::config(format!("sharded FP: unplanned type {ty}")))?;
            for (l, &g) in part.shards[s].owned[ty].iter().enumerate() {
                target.set_row(g as usize, h.row(l));
            }
        }
    }
    profile.record(
        vec![dr_exec("ShardMerge", fp_bytes, t0.elapsed().as_nanos() as u64)],
        StageId::FeatureProjection,
        None,
        0,
        0,
    );

    // ③ halo exchange + NA per shard, spread over shard threads
    let projected_ref = &projected;
    let na_outs: Vec<NaOut> = match backend.as_sync() {
        Some(sync) if threads > 1 => {
            run_shards_parallel(k, threads, &thread_of, |s| {
                na_shard_task(sync, &part.shards[s], projected_ref)
            })?
        }
        _ => (0..k)
            .map(|s| na_shard_task(backend, &part.shards[s], projected_ref))
            .collect::<Result<Vec<_>>>()?,
    };
    let mut shard_na: Vec<Vec<Tensor>> = Vec::with_capacity(k);
    for (s, (halo_events, subs)) in na_outs.into_iter().enumerate() {
        profile.record(halo_events, StageId::NeighborAggregation, None, thread_of[s], 0);
        let mut outs = Vec::with_capacity(subs.len());
        for (si, (events, t)) in subs.into_iter().enumerate() {
            profile.record(
                events,
                StageId::NeighborAggregation,
                Some(plan.subgraphs.subgraphs[si].name.as_str()),
                thread_of[s],
                0,
            );
            outs.push(t);
        }
        shard_na.push(outs);
    }

    // barrier: owner-computes merge of the per-shard NA rows
    let t0 = std::time::Instant::now();
    let p = plan.num_subgraphs();
    let mut na_results = Vec::with_capacity(p);
    let mut na_bytes = 0u64;
    for si in 0..p {
        let sg = &plan.subgraphs.subgraphs[si];
        let cols = shard_na[0][si].cols();
        let mut out = Tensor::zeros(sg.adj.n_rows, cols);
        for (s, outs) in shard_na.iter().enumerate() {
            for &(l, g) in &part.shards[s].merge[sg.dst_type] {
                out.set_row(g as usize, outs[si].row(l as usize));
            }
        }
        na_bytes += out.bytes() as u64;
        na_results.push(out);
    }
    profile.record(
        vec![dr_exec("ShardMerge", na_bytes, t0.elapsed().as_nanos() as u64)],
        StageId::NeighborAggregation,
        None,
        0,
        0,
    );

    // barrier, then ④ SA on the main thread over the merged tensors
    let output = backend.semantic_aggregation(scratch, plan, &na_results)?;
    record_advance(&mut profile, scratch, StageId::SemanticAggregation, None, 0, 0);
    recycle_projected(scratch, projected);

    profile.attach_metrics(gpu);
    let effective = SchedulePolicy::InterSubgraphParallel { workers: threads };
    let mut report = schedule::analyze(&profile, threads, false, effective, gpu);
    report.sharding = Some(part.info());
    Ok(StagedRun { output, na_results, profile, report })
}

/// Run one task per shard, LPT-packed onto `threads` worker-pool tasks
/// (`thread_of` from [`lpt_assign`] over the shard costs). Results come
/// back indexed by shard. Dispatching through the shared pool (instead
/// of ad-hoc scoped threads) means shard tasks and intra-kernel
/// `parallel_for` can never oversubscribe each other. Callers without a
/// thread-safe backend view run the same shard schedule inline instead.
fn run_shards_parallel<T: Send>(
    k: usize,
    threads: usize,
    thread_of: &[usize],
    f: impl Fn(usize) -> Result<T> + Sync,
) -> Result<Vec<T>> {
    let mut slots: Vec<Option<T>> = (0..k).map(|_| None).collect();
    let per_thread: Vec<Result<Vec<(usize, T)>>> =
        crate::parallel::parallel_map(threads, |t| -> Result<Vec<(usize, T)>> {
            (0..k)
                .filter(|&s| thread_of[s] == t)
                .map(|s| f(s).map(|r| (s, r)))
                .collect()
        });
    for r in per_thread {
        for (s, out) in r? {
            slots[s] = Some(out);
        }
    }
    slots
        .into_iter()
        .enumerate()
        .map(|(s, o)| o.ok_or_else(|| Error::config(format!("shard {s} never executed"))))
        .collect()
}

/// Stage ② for one shard: project exactly the rows this shard owns, per
/// planned type, through the backend's row-sliced projection entry point
/// (whole-type projection + slice when the backend has none).
fn fp_shard_task<B: ExecBackend + ?Sized>(
    backend: &B,
    shard: &Shard,
    ctx: &mut Ctx,
    plan: &ModelPlan,
    hg: &HeteroGraph,
) -> Result<Vec<(usize, Tensor)>> {
    let mut out = Vec::new();
    for (&ty, w) in &plan.weights.proj {
        let ids = &shard.owned[ty];
        if ids.is_empty() {
            continue;
        }
        let x = plan.weights.embed.get(&ty).unwrap_or_else(|| hg.features(ty));
        let x_rows = index_select(ctx, x, ids)?;
        let h = match backend.project_features(ctx, plan, ty, &x_rows)? {
            Some(h) => h,
            None => {
                let full = backend.project_type(ctx, plan, hg, ty)?.ok_or_else(|| {
                    Error::config(format!("sharded FP: type {ty} has no projection path"))
                })?;
                index_select(ctx, &full, ids)?
            }
        };
        if h.shape() != (ids.len(), w.cols()) {
            return Err(Error::shape(format!(
                "sharded FP: type {ty} projected {:?}, expected ({}, {})",
                h.shape(),
                ids.len(),
                w.cols()
            )));
        }
        out.push((ty, h));
    }
    Ok(out)
}

/// Stage ③ for one shard: gather the local feature slice (the halo
/// exchange), then aggregate every subgraph's owned rows over the local
/// sub-CSRs. Returns (halo events, per-subgraph (events, result)).
fn na_shard_task<B: ExecBackend + ?Sized>(
    backend: &B,
    shard: &Shard,
    projected: &Projected,
) -> Result<NaOut> {
    let mut ctx = backend.make_ctx();
    let mut local: Projected = BTreeMap::new();
    for (&ty, h) in projected {
        local.insert(ty, halo_exchange(&mut ctx, h, &shard.nodes[ty]));
    }
    let halo_events = ctx.drain();
    let mut subs = Vec::with_capacity(shard.plan.num_subgraphs());
    for si in 0..shard.plan.num_subgraphs() {
        let t = backend.neighbor_aggregation(&mut ctx, &shard.plan, si, &local)?;
        subs.push((ctx.drain(), t));
    }
    Ok((halo_events, subs))
}

/// Gather a shard's local rows from a merged global matrix — owned rows
/// from the shard's own stage-② output, halo rows from their owners'.
fn halo_exchange(ctx: &mut Ctx, h: &Tensor, ids: &[u32]) -> Tensor {
    let t0 = std::time::Instant::now();
    let mut out = Tensor::zeros(ids.len(), h.cols());
    for (l, &g) in ids.iter().enumerate() {
        out.set_row(l, h.row(g as usize));
    }
    let nanos = t0.elapsed().as_nanos() as u64;
    let bytes = out.bytes() as u64;
    ctx.push(
        "HaloExchange",
        KernelType::DataRearrange,
        KernelCounters {
            flops: 0,
            bytes_read: bytes + ids.len() as u64 * 4,
            bytes_written: bytes,
        },
        nanos,
        None,
    );
    out
}

/// A data-rearrange kernel record for the owner-computes merges.
fn dr_exec(name: &'static str, bytes: u64, nanos: u64) -> KernelExec {
    KernelExec {
        name,
        ktype: KernelType::DataRearrange,
        counters: KernelCounters { flops: 0, bytes_read: bytes, bytes_written: bytes },
        wall_nanos: nanos,
        trace: None,
    }
}

// ---------------------------------------------------------------------------
// Distributed execution
// ---------------------------------------------------------------------------

/// Execute the full-graph forward over a [`Cluster`] of shard workers —
/// the same owner-computes FP/NA/SA plan as [`execute_sharded`], with
/// the shard boundary promoted from scoped threads to a message fabric.
///
/// One run is one *wave* ([`Cluster::begin_wave`]): an `Epoch`
/// broadcast, then one [`Cluster::stage_round`] per compute stage.
///
/// * **② FP** — the coordinator sends each shard's owner an `FpRows`
///   request marker; the worker runs [the same FP task][execute_sharded]
///   over its owned rows and replies one `FpRows` block per planned
///   type. The coordinator scatters the disjoint blocks into the global
///   per-type matrices (`ShardMerge`).
/// * **Halo exchange** — the coordinator gathers each shard's local
///   slice (owned ∪ halo, ascending global ids) from the merged
///   matrices (`HaloExchange`, exactly as the sharded path) and ships
///   it as one `Halo` block per type.
/// * **③ NA** — the worker rebuilds its local projection view from the
///   received blocks (f32 rows are wire-bit-exact), aggregates every
///   subgraph of its shard plan, and replies one `NaRows` block per
///   subgraph carrying only its owner-computes merge rows. The
///   coordinator scatters them into the global NA tensors
///   (`ShardMerge`), then **④ SA** runs once at the coordinator.
///
/// Worker death mid-wave is handled inside the stage rounds: the
/// heartbeat timeout retires the silent worker, its shards re-place
/// onto survivors from the coordinator's retained [`Partition`], and
/// the in-flight round replays on the new owner. Kernel events are
/// slotted per shard and overwritten on replay, so the profile counts
/// every shard's compute exactly once; per-stage `WireTransfer` DR
/// kernels carry the transport byte deltas with zero wall time, keeping
/// the profile's kernel set seed-deterministic.
#[allow(clippy::too_many_arguments)]
pub fn execute_distributed(
    backend: &dyn ExecBackend,
    gpu: &GpuModel,
    plan: &ModelPlan,
    hg: &HeteroGraph,
    part: &Partition,
    cluster: &mut Cluster,
    scratch: &mut Ctx,
) -> Result<StagedRun> {
    scratch.events.clear();
    let k = part.num_shards();
    if cluster.placement().len() != k {
        return Err(Error::shape(format!(
            "distributed: cluster places {} shards, partition has {k}",
            cluster.placement().len()
        )));
    }
    cluster.begin_wave()?;
    let mut profile = Profile {
        subgraph_build_nanos: plan.subgraphs.build_nanos,
        pool_threads: crate::parallel::current_threads(),
        ..Default::default()
    };
    let wire_start = cluster.transport_stats();

    // ② FP round: request is a marker; the response is one FpRows block
    // per planned type with owned rows on that shard.
    let fp_expected: Vec<usize> = (0..k)
        .map(|s| {
            plan.weights
                .proj
                .keys()
                .filter(|&&ty| !part.shards[s].owned[ty].is_empty())
                .count()
        })
        .collect();
    let mut fp_events: Vec<Vec<KernelExec>> = vec![Vec::new(); k];
    let fp_replies = cluster.stage_round(
        k,
        &mut |s| {
            Ok(vec![Message::FpRows {
                shard: s as u32,
                ty: u32::MAX, // request marker: "project your owned rows"
                block: RowBlock::empty(),
            }])
        },
        &mut |s, _req| {
            let mut ctx = backend.make_ctx();
            let rows = fp_shard_task(backend, &part.shards[s], &mut ctx, plan, hg)?;
            fp_events[s] = ctx.drain(); // overwritten on replay: counted once
            Ok(rows
                .into_iter()
                .map(|(ty, h)| Message::FpRows {
                    shard: s as u32,
                    ty: ty as u32,
                    block: RowBlock {
                        ids: part.shards[s].owned[ty].clone(),
                        cols: h.cols() as u32,
                        data: h.into_vec(),
                    },
                })
                .collect())
        },
        &|s| fp_expected[s],
    )?;
    for (s, events) in fp_events.iter_mut().enumerate() {
        profile.record(
            std::mem::take(events),
            StageId::FeatureProjection,
            None,
            cluster.worker_for(s),
            0,
        );
    }

    // stage-② merge at the coordinator: scatter the received blocks into
    // the global per-type matrices.
    let t0 = std::time::Instant::now();
    let mut projected: Projected = BTreeMap::new();
    for (&ty, w) in &plan.weights.proj {
        let rows = plan
            .weights
            .embed
            .get(&ty)
            .map(|e| e.rows())
            .unwrap_or_else(|| hg.node_type(ty).count);
        projected.insert(ty, Tensor::zeros(rows, w.cols()));
    }
    let mut fp_bytes = 0u64;
    for replies in &fp_replies {
        for msg in replies {
            let Message::FpRows { ty, block, .. } = msg else {
                return Err(Error::config("distributed FP: unexpected reply variant"));
            };
            block.validate()?;
            let cols = block.cols as usize;
            fp_bytes += (block.data.len() * 4) as u64;
            let target = projected
                .get_mut(&(*ty as usize))
                .ok_or_else(|| Error::config(format!("distributed FP: unplanned type {ty}")))?;
            for (i, &g) in block.ids.iter().enumerate() {
                target.set_row(g as usize, &block.data[i * cols..(i + 1) * cols]);
            }
        }
    }
    profile.record(
        vec![dr_exec("ShardMerge", fp_bytes, t0.elapsed().as_nanos() as u64)],
        StageId::FeatureProjection,
        None,
        0,
        0,
    );
    let wire_fp = cluster.transport_stats();
    profile.record(
        vec![dr_exec("WireTransfer", wire_fp.bytes - wire_start.bytes, 0)],
        StageId::FeatureProjection,
        None,
        0,
        0,
    );

    // Halo exchange at the coordinator: gather each shard's local slice
    // from the merged matrices (same kernels as the sharded path), ship
    // the slices as the NA-round request blocks.
    let mut halo_reqs: Vec<Vec<Message>> = Vec::with_capacity(k);
    for s in 0..k {
        let mut msgs = Vec::with_capacity(projected.len());
        for (&ty, h) in &projected {
            let ids = &part.shards[s].nodes[ty];
            let local = halo_exchange(scratch, h, ids);
            msgs.push(Message::Halo {
                shard: s as u32,
                ty: ty as u32,
                block: RowBlock {
                    ids: ids.clone(),
                    cols: local.cols() as u32,
                    data: local.into_vec(),
                },
            });
        }
        let events = scratch.drain();
        profile.record(events, StageId::NeighborAggregation, None, cluster.worker_for(s), 0);
        halo_reqs.push(msgs);
    }

    // ③ NA round: workers aggregate over their wire-received local view
    // and reply only their owner-computes merge rows.
    let p = plan.num_subgraphs();
    let mut na_events: Vec<Vec<(usize, Vec<KernelExec>)>> = vec![Vec::new(); k];
    let na_replies = cluster.stage_round(
        k,
        &mut |s| Ok(halo_reqs[s].clone()),
        &mut |s, req| {
            let shard = &part.shards[s];
            let mut ctx = backend.make_ctx();
            let mut local: Projected = BTreeMap::new();
            for msg in req {
                let Message::Halo { ty, block, .. } = msg else {
                    return Err(Error::config("distributed NA: unexpected request variant"));
                };
                block.validate()?;
                local.insert(
                    *ty as usize,
                    Tensor::from_vec(block.ids.len(), block.cols as usize, block.data.clone())?,
                );
            }
            let mut events = Vec::with_capacity(p);
            let mut out = Vec::with_capacity(p);
            for si in 0..shard.plan.num_subgraphs() {
                let t = backend.neighbor_aggregation(&mut ctx, &shard.plan, si, &local)?;
                events.push((si, ctx.drain()));
                let sg = &shard.plan.subgraphs.subgraphs[si];
                let merge = &shard.merge[sg.dst_type];
                let cols = t.cols();
                let mut ids = Vec::with_capacity(merge.len());
                let mut data = Vec::with_capacity(merge.len() * cols);
                for &(l, g) in merge {
                    ids.push(g);
                    data.extend_from_slice(t.row(l as usize));
                }
                out.push(Message::NaRows {
                    shard: s as u32,
                    subgraph: si as u32,
                    block: RowBlock { ids, cols: cols as u32, data },
                });
            }
            na_events[s] = events; // overwritten on replay: counted once
            Ok(out)
        },
        &|_| p,
    )?;
    for (s, per_sub) in na_events.iter_mut().enumerate() {
        for (si, events) in std::mem::take(per_sub) {
            profile.record(
                events,
                StageId::NeighborAggregation,
                Some(plan.subgraphs.subgraphs[si].name.as_str()),
                cluster.worker_for(s),
                0,
            );
        }
    }

    // owner-computes merge of the received NA rows at the coordinator
    let t0 = std::time::Instant::now();
    let mut merged: Vec<Option<Tensor>> = (0..p).map(|_| None).collect();
    for replies in &na_replies {
        for msg in replies {
            let Message::NaRows { subgraph, block, .. } = msg else {
                return Err(Error::config("distributed NA: unexpected reply variant"));
            };
            block.validate()?;
            let si = *subgraph as usize;
            if si >= p {
                return Err(Error::shape(format!("distributed NA: subgraph {si} out of range")));
            }
            let sg = &plan.subgraphs.subgraphs[si];
            let cols = block.cols as usize;
            let out = merged[si].get_or_insert_with(|| Tensor::zeros(sg.adj.n_rows, cols));
            for (i, &g) in block.ids.iter().enumerate() {
                out.set_row(g as usize, &block.data[i * cols..(i + 1) * cols]);
            }
        }
    }
    let mut na_results = Vec::with_capacity(p);
    let mut na_bytes = 0u64;
    for (si, slot) in merged.into_iter().enumerate() {
        let out = slot
            .ok_or_else(|| Error::config(format!("distributed NA: subgraph {si} never merged")))?;
        na_bytes += out.bytes() as u64;
        na_results.push(out);
    }
    profile.record(
        vec![dr_exec("ShardMerge", na_bytes, t0.elapsed().as_nanos() as u64)],
        StageId::NeighborAggregation,
        None,
        0,
        0,
    );
    let wire_na = cluster.transport_stats();
    profile.record(
        vec![dr_exec("WireTransfer", wire_na.bytes - wire_fp.bytes, 0)],
        StageId::NeighborAggregation,
        None,
        0,
        0,
    );

    // ④ SA once, at the coordinator, over the merged tensors
    let output = backend.semantic_aggregation(scratch, plan, &na_results)?;
    record_advance(&mut profile, scratch, StageId::SemanticAggregation, None, 0, 0);
    recycle_projected(scratch, projected);

    profile.attach_metrics(gpu);
    let live = cluster.live_workers().len().max(1);
    let effective = SchedulePolicy::InterSubgraphParallel { workers: live };
    let mut report = schedule::analyze(&profile, live, false, effective, gpu);
    report.sharding = Some(part.info());
    Ok(StagedRun { output, na_results, profile, report })
}

/// Fused tasks on the calling thread with per-virtual-worker projection
/// maps (same redundancy semantics as the threaded path); executes
/// through the session `scratch` so kernel outputs draw on its arena.
fn virtual_fused(
    backend: &dyn ExecBackend,
    plan: &ModelPlan,
    hg: &HeteroGraph,
    assignment: &[usize],
    workers: usize,
    scratch: &mut Ctx,
) -> Result<Vec<Vec<TaskOut>>> {
    let p = assignment.len();
    let mut out: Vec<Vec<TaskOut>> = (0..workers).map(|_| Vec::new()).collect();
    for w in 0..workers {
        let mut local_proj: Projected = BTreeMap::new();
        for i in (0..p).filter(|&i| assignment[i] == w) {
            let t = fused_task(backend, scratch, plan, hg, &mut local_proj, i)?;
            out[w].push((i, scratch.drain(), t));
        }
    }
    Ok(out)
}
