//! The session's schedule executor: one code path driving any
//! [`ExecBackend`] under any [`SchedulePolicy`].
//!
//! This subsumes what `Engine::run` (sequential) and the old
//! `Coordinator` (parallel / fused / mixing) used to implement
//! separately. Policies that spread Neighbor Aggregation over workers
//! use real threads when the backend is thread-safe
//! ([`ExecBackend::as_sync`]); otherwise the same worker assignment is
//! executed on one thread ("virtual workers") and the modeled schedule
//! analysis — the honest instrument, per DESIGN.md §4 — is identical.

use std::collections::BTreeMap;

use crate::coordinator::schedule::{self, lpt_assign, ScheduleReport};
use crate::gpumodel::GpuModel;
use crate::graph::HeteroGraph;
use crate::kernels::{Ctx, KernelExec};
use crate::models::ModelPlan;
use crate::profiler::{Profile, StageId};
use crate::tensor::Tensor;
use crate::{Error, Result};

use super::backend::{ExecBackend, Projected, SyncExecBackend};
use super::SchedulePolicy;

/// Everything one staged execution produces.
#[derive(Debug)]
pub struct StagedRun {
    /// Final embeddings of the plan's target node type.
    pub output: Tensor,
    /// Per-subgraph Neighbor Aggregation results.
    pub na_results: Vec<Tensor>,
    /// Kernel-level profile (worker-attributed, modeled metrics attached).
    pub profile: Profile,
    /// Modeled schedule analysis.
    pub report: ScheduleReport,
}

/// Per-subgraph NA cost estimate for LPT assignment (nnz dominates every
/// NA variant).
fn na_costs(plan: &ModelPlan) -> Vec<f64> {
    plan.subgraphs
        .subgraphs
        .iter()
        .map(|sg| sg.adj.nnz() as f64 + 1.0)
        .collect()
}

/// Drain ctx events into the profile under one attribution; returns the
/// advanced wallclock cursor.
fn record_advance(
    profile: &mut Profile,
    ctx: &mut Ctx,
    stage: StageId,
    subgraph: Option<&str>,
    worker: usize,
    cursor: u64,
) -> u64 {
    let dur: u64 = ctx.events.iter().map(|e| e.wall_nanos).sum();
    profile.record_drain(&mut ctx.events, stage, subgraph, worker, cursor);
    cursor + dur
}

/// Execute `plan` on `backend` under `policy`. `scratch` is the
/// session-owned kernel context reused across runs (its event buffer's
/// allocation survives, so repeat runs skip the warm-up allocations).
pub fn execute(
    backend: &dyn ExecBackend,
    gpu: &GpuModel,
    plan: &ModelPlan,
    hg: &HeteroGraph,
    policy: SchedulePolicy,
    scratch: &mut Ctx,
) -> Result<StagedRun> {
    // a previous run that errored mid-stage may have left events behind;
    // they must not leak into this run's profile
    scratch.events.clear();
    match policy {
        SchedulePolicy::Sequential => run_sequential(backend, gpu, plan, hg, scratch),
        SchedulePolicy::InterSubgraphParallel { workers } => {
            run_scheduled(backend, gpu, plan, hg, workers.max(1), false, policy, scratch)
        }
        SchedulePolicy::BoundAwareMixing { workers } => {
            run_scheduled(backend, gpu, plan, hg, workers.max(1), true, policy, scratch)
        }
        SchedulePolicy::FusedSubgraph { workers } => {
            run_fused(backend, gpu, plan, hg, workers.max(1), policy, scratch)
        }
    }
}

/// FP + NA only (the Fig 5a/5b sweeps time NA in isolation).
pub fn run_na_only(
    backend: &dyn ExecBackend,
    gpu: &GpuModel,
    plan: &ModelPlan,
    hg: &HeteroGraph,
    scratch: &mut Ctx,
) -> Result<(Vec<Tensor>, Profile)> {
    scratch.events.clear();
    let mut profile = Profile {
        subgraph_build_nanos: plan.subgraphs.build_nanos,
        ..Default::default()
    };
    let projected = backend.feature_projection(scratch, plan, hg)?;
    let mut cursor =
        record_advance(&mut profile, scratch, StageId::FeatureProjection, None, 0, 0);
    let mut na_results = Vec::with_capacity(plan.num_subgraphs());
    for i in 0..plan.num_subgraphs() {
        let name = plan.subgraphs.subgraphs[i].name.clone();
        let out = backend.neighbor_aggregation(scratch, plan, i, &projected)?;
        cursor = record_advance(
            &mut profile,
            scratch,
            StageId::NeighborAggregation,
            Some(name.as_str()),
            0,
            cursor,
        );
        na_results.push(out);
    }
    profile.attach_metrics(gpu);
    Ok((na_results, profile))
}

/// Serial FP → NA(sg0..sgP) → SA, single stream (the DGL execution the
/// paper profiles).
fn run_sequential(
    backend: &dyn ExecBackend,
    gpu: &GpuModel,
    plan: &ModelPlan,
    hg: &HeteroGraph,
    scratch: &mut Ctx,
) -> Result<StagedRun> {
    let mut profile = Profile {
        subgraph_build_nanos: plan.subgraphs.build_nanos,
        ..Default::default()
    };
    let projected = backend.feature_projection(scratch, plan, hg)?;
    let mut cursor =
        record_advance(&mut profile, scratch, StageId::FeatureProjection, None, 0, 0);
    let mut na_results = Vec::with_capacity(plan.num_subgraphs());
    for i in 0..plan.num_subgraphs() {
        let name = plan.subgraphs.subgraphs[i].name.clone();
        let out = backend.neighbor_aggregation(scratch, plan, i, &projected)?;
        cursor = record_advance(
            &mut profile,
            scratch,
            StageId::NeighborAggregation,
            Some(name.as_str()),
            0,
            cursor,
        );
        na_results.push(out);
    }
    let output = backend.semantic_aggregation(scratch, plan, &na_results)?;
    let _ = record_advance(
        &mut profile,
        scratch,
        StageId::SemanticAggregation,
        None,
        0,
        cursor,
    );
    profile.attach_metrics(gpu);
    let report =
        schedule::analyze(&profile, 1, false, SchedulePolicy::Sequential, gpu);
    Ok(StagedRun { output, na_results, profile, report })
}

type TaskOut = (usize, Vec<KernelExec>, Tensor);

/// FP serial → NA across workers → barrier → SA.
#[allow(clippy::too_many_arguments)]
fn run_scheduled(
    backend: &dyn ExecBackend,
    gpu: &GpuModel,
    plan: &ModelPlan,
    hg: &HeteroGraph,
    workers: usize,
    mixing: bool,
    policy: SchedulePolicy,
    scratch: &mut Ctx,
) -> Result<StagedRun> {
    let mut profile = Profile {
        subgraph_build_nanos: plan.subgraphs.build_nanos,
        ..Default::default()
    };

    // ② FP (single stream, worker 0)
    let projected = backend.feature_projection(scratch, plan, hg)?;
    record_advance(&mut profile, scratch, StageId::FeatureProjection, None, 0, 0);

    let assignment = lpt_assign(&na_costs(plan), workers);
    let p = plan.num_subgraphs();

    // ③ NA spread over workers (real threads when the backend allows)
    let mut task_outs: Vec<Option<TaskOut>> = (0..p).map(|_| None).collect();
    let worker_outputs = match backend.as_sync() {
        Some(sync) if workers > 1 => {
            parallel_na(sync, plan, &projected, &assignment, workers)?
        }
        _ => virtual_na(backend, plan, &projected, &assignment, workers)?,
    };
    for per_worker in worker_outputs {
        for (i, events, t) in per_worker {
            task_outs[i] = Some((i, events, t));
        }
    }
    let mut na_results = Vec::with_capacity(p);
    for (i, slot) in task_outs.into_iter().enumerate() {
        let (_, events, t) = slot
            .ok_or_else(|| Error::config(format!("subgraph {i} was never scheduled")))?;
        profile.record(
            events,
            StageId::NeighborAggregation,
            Some(plan.subgraphs.subgraphs[i].name.as_str()),
            assignment[i],
            0,
        );
        na_results.push(t);
    }

    // barrier, then ④ SA on worker 0
    let output = backend.semantic_aggregation(scratch, plan, &na_results)?;
    record_advance(&mut profile, scratch, StageId::SemanticAggregation, None, 0, 0);

    profile.attach_metrics(gpu);
    let report = schedule::analyze(&profile, workers, mixing, policy, gpu);
    Ok(StagedRun { output, na_results, profile, report })
}

/// NA tasks on real threads, one per worker.
fn parallel_na(
    backend: &dyn SyncExecBackend,
    plan: &ModelPlan,
    projected: &Projected,
    assignment: &[usize],
    workers: usize,
) -> Result<Vec<Vec<TaskOut>>> {
    let p = assignment.len();
    std::thread::scope(|scope| {
        let mut handles = Vec::new();
        for w in 0..workers {
            let my_subgraphs: Vec<usize> =
                (0..p).filter(|&i| assignment[i] == w).collect();
            handles.push(scope.spawn(move || -> Result<Vec<TaskOut>> {
                let mut out = Vec::new();
                for i in my_subgraphs {
                    let mut wctx = backend.make_ctx();
                    let t = backend.neighbor_aggregation(&mut wctx, plan, i, projected)?;
                    out.push((i, wctx.drain(), t));
                }
                Ok(out)
            }));
        }
        handles
            .into_iter()
            .map(|h| h.join().expect("NA worker panicked"))
            .collect()
    })
}

/// NA tasks executed on the calling thread, attributed to their assigned
/// (virtual) workers — used for backends without a thread-safe view.
fn virtual_na(
    backend: &dyn ExecBackend,
    plan: &ModelPlan,
    projected: &Projected,
    assignment: &[usize],
    workers: usize,
) -> Result<Vec<Vec<TaskOut>>> {
    let p = assignment.len();
    let mut out: Vec<Vec<TaskOut>> = (0..workers).map(|_| Vec::new()).collect();
    for w in 0..workers {
        for i in (0..p).filter(|&i| assignment[i] == w) {
            let mut wctx = backend.make_ctx();
            let t = backend.neighbor_aggregation(&mut wctx, plan, i, projected)?;
            out[w].push((i, wctx.drain(), t));
        }
    }
    Ok(out)
}

/// §5 guideline 2: per-subgraph fused (FP + NA) tasks.
///
/// Each worker projects the types *its* subgraphs need (first use wins
/// within a worker); types shared across workers are projected
/// redundantly — that duplication is the fusion trade-off the ablation
/// quantifies. Fused tasks attribute all their kernels (including the
/// projection sgemms) to NA: that is what fusion means for the schedule.
fn run_fused(
    backend: &dyn ExecBackend,
    gpu: &GpuModel,
    plan: &ModelPlan,
    hg: &HeteroGraph,
    workers: usize,
    policy: SchedulePolicy,
    scratch: &mut Ctx,
) -> Result<StagedRun> {
    let mut profile = Profile {
        subgraph_build_nanos: plan.subgraphs.build_nanos,
        ..Default::default()
    };
    let assignment = lpt_assign(&na_costs(plan), workers);
    let p = plan.num_subgraphs();

    let worker_outputs = match backend.as_sync() {
        Some(sync) if workers > 1 => {
            parallel_fused(sync, plan, hg, &assignment, workers)?
        }
        _ => virtual_fused(backend, plan, hg, &assignment, workers)?,
    };

    let mut results: Vec<Option<Tensor>> = (0..p).map(|_| None).collect();
    for per_worker in worker_outputs {
        for (i, events, t) in per_worker {
            profile.record(
                events,
                StageId::NeighborAggregation,
                Some(plan.subgraphs.subgraphs[i].name.as_str()),
                assignment[i],
                0,
            );
            results[i] = Some(t);
        }
    }
    let na_results: Vec<Tensor> = results
        .into_iter()
        .enumerate()
        .map(|(i, r)| r.ok_or_else(|| Error::config(format!("subgraph {i} missing"))))
        .collect::<Result<_>>()?;

    let output = backend.semantic_aggregation(scratch, plan, &na_results)?;
    record_advance(&mut profile, scratch, StageId::SemanticAggregation, None, 0, 0);

    profile.attach_metrics(gpu);
    let report = schedule::analyze(&profile, workers, false, policy, gpu);
    Ok(StagedRun { output, na_results, profile, report })
}

/// One fused (FP+NA) task: project the subgraph's endpoint types into
/// the worker-local map if absent, then aggregate. Generic over the
/// (possibly unsized) backend so both `dyn ExecBackend` and
/// `dyn SyncExecBackend` callers work without trait upcasting.
fn fused_task<B: ExecBackend + ?Sized>(
    backend: &B,
    ctx: &mut Ctx,
    plan: &ModelPlan,
    hg: &HeteroGraph,
    local_proj: &mut Projected,
    i: usize,
) -> Result<Tensor> {
    let sg = &plan.subgraphs.subgraphs[i];
    for ty in [sg.src_type, sg.dst_type] {
        if let std::collections::btree_map::Entry::Vacant(slot) = local_proj.entry(ty) {
            if let Some(h) = backend.project_type(ctx, plan, hg, ty)? {
                slot.insert(h);
            }
        }
    }
    backend.neighbor_aggregation(ctx, plan, i, local_proj)
}

/// Fused tasks on real threads.
fn parallel_fused(
    backend: &dyn SyncExecBackend,
    plan: &ModelPlan,
    hg: &HeteroGraph,
    assignment: &[usize],
    workers: usize,
) -> Result<Vec<Vec<TaskOut>>> {
    let p = assignment.len();
    std::thread::scope(|scope| {
        let mut handles = Vec::new();
        for w in 0..workers {
            let my_subgraphs: Vec<usize> =
                (0..p).filter(|&i| assignment[i] == w).collect();
            handles.push(scope.spawn(move || -> Result<Vec<TaskOut>> {
                let mut out = Vec::new();
                let mut local_proj: Projected = BTreeMap::new();
                for i in my_subgraphs {
                    let mut wctx = backend.make_ctx();
                    let t = fused_task(backend, &mut wctx, plan, hg, &mut local_proj, i)?;
                    out.push((i, wctx.drain(), t));
                }
                Ok(out)
            }));
        }
        handles
            .into_iter()
            .map(|h| h.join().expect("fused worker panicked"))
            .collect()
    })
}

/// Fused tasks on the calling thread with per-virtual-worker projection
/// maps (same redundancy semantics as the threaded path).
fn virtual_fused(
    backend: &dyn ExecBackend,
    plan: &ModelPlan,
    hg: &HeteroGraph,
    assignment: &[usize],
    workers: usize,
) -> Result<Vec<Vec<TaskOut>>> {
    let p = assignment.len();
    let mut out: Vec<Vec<TaskOut>> = (0..workers).map(|_| Vec::new()).collect();
    for w in 0..workers {
        let mut local_proj: Projected = BTreeMap::new();
        for i in (0..p).filter(|&i| assignment[i] == w) {
            let mut wctx = backend.make_ctx();
            let t = fused_task(backend, &mut wctx, plan, hg, &mut local_proj, i)?;
            out[w].push((i, wctx.drain(), t));
        }
    }
    Ok(out)
}
