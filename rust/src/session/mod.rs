//! The unified execution surface: one [`Session`] owns the graph, the
//! plan, the GPU model and the compiled/cached state, and composes
//! *backend × schedule × profiling* behind a builder.
//!
//! The paper's core finding is that HGNN execution is a schedule over
//! stages, not a single kernel stream; a session makes that schedule a
//! first-class, swappable policy ([`SchedulePolicy`]) over a pluggable
//! execution backend ([`ExecBackend`]), and keeps everything reusable
//! across runs and served batches (plan, weights, compiled artifacts,
//! kernel-context scratch) instead of rebuilding per call.
//!
//! The serving path additionally composes with mini-batch metapath
//! sampling ([`SessionBuilder::sampling`]): [`Session::run_batch`] then
//! executes the stages over a [`crate::sampler::SampledSubgraph`] of the
//! requested seeds, so per-batch cost scales with the batch instead of
//! the graph. Stacking [`SessionBuilder::reuse`] on top memoizes the
//! batch-invariant stage results (projection rows, full-coverage
//! aggregates) across batches — see [`crate::reuse`] — so overlapping
//! request streams stop re-paying the dominant stages for the same
//! nodes.
//!
//! ```no_run
//! use hgnn_char::prelude::*;
//!
//! let mut session = Session::builder()
//!     .dataset(DatasetId::Dblp)
//!     .model(ModelId::Han)
//!     .schedule(SchedulePolicy::InterSubgraphParallel { workers: 4 })
//!     .profiling(Profiling::Traces)
//!     .build()?;
//! let run = session.run()?;
//! println!("{}", run.profile.stage_breakdown());
//! println!("{}", run.report.summary());
//! # Ok::<(), hgnn_char::Error>(())
//! ```

pub mod backend;
pub mod exec;

use std::path::PathBuf;
use std::time::Instant;

use crate::cluster::{
    Cluster, ClusterSpec, ClusterStats, Message, RowBlock, SimTransport, Transport,
};
use crate::coordinator::schedule::{self, ScheduleReport};
use crate::datasets::{self, DatasetId, DatasetScale};
use crate::dynamic::{self, DynamicSpec, EpochReport, GraphSnapshot, GraphUpdate, UpdateLog};
use crate::gpumodel::GpuModel;
use crate::graph::HeteroGraph;
use crate::kernels::quant::{QuantMatrix, QuantSpec};
use crate::kernels::Ctx;
use crate::models::{self, ModelConfig, ModelId, ModelPlan, ModelWeights};
use crate::partition::Partition;
use crate::profiler::Profile;
use crate::reuse::{ReuseCache, ReuseStats};
use crate::sampler::{NeighborSampler, SampledSubgraph};
use crate::tensor::Tensor;
use crate::train::{self, EpochStats, FitReport, TrainConfig, Trainer};
use crate::util::Pcg32;
use crate::{Error, Result};

pub use backend::{
    BackendCaps, ExecBackend, NativeBackend, PjrtBackend, Projected, SyncAsExec,
    SyncExecBackend,
};
pub use crate::coordinator::serve::{ServeConfig, ServeStats, Server};
pub use crate::serving::{
    AsyncServer, BatchReply, ServeError, ServingConfig, SubmitOpts,
};
pub use crate::partition::PartitionSpec;
pub use crate::reuse::ReuseSpec;
pub use crate::sampler::SamplingSpec;
pub use exec::StagedRun;

/// How the session schedules the stages.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SchedulePolicy {
    /// Serial FP → NA(sg0..sgP) → SA, single stream (what the paper
    /// profiles on DGL).
    Sequential,
    /// FP serial, NA subgraphs across `workers` streams, barrier, SA
    /// (the Fig 5c observation applied).
    InterSubgraphParallel {
        /// Concurrent NA streams.
        workers: usize,
    },
    /// Per-subgraph (FP+NA) fused tasks across `workers` streams
    /// (§5 guideline 2).
    FusedSubgraph {
        /// Concurrent task streams.
        workers: usize,
    },
    /// Inter-subgraph parallel + compute/memory co-scheduling analysis
    /// (§5 guideline 1).
    BoundAwareMixing {
        /// Concurrent NA streams.
        workers: usize,
    },
}

impl SchedulePolicy {
    /// Every policy shape at a given worker count (test/report sweeps).
    pub fn all(workers: usize) -> [SchedulePolicy; 4] {
        [
            SchedulePolicy::Sequential,
            SchedulePolicy::InterSubgraphParallel { workers },
            SchedulePolicy::FusedSubgraph { workers },
            SchedulePolicy::BoundAwareMixing { workers },
        ]
    }

    /// Short label for reports.
    pub fn label(self) -> String {
        match self {
            SchedulePolicy::Sequential => "sequential".into(),
            SchedulePolicy::InterSubgraphParallel { workers } => {
                format!("inter-subgraph x{workers}")
            }
            SchedulePolicy::FusedSubgraph { workers } => format!("fused-subgraph x{workers}"),
            SchedulePolicy::BoundAwareMixing { workers } => format!("bound-aware-mix x{workers}"),
        }
    }
}

/// Profiling depth for a session.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum Profiling {
    /// Exact counters per kernel, no gather traces (cheapest useful
    /// level; stage/type breakdowns are exact).
    #[default]
    Counters,
    /// Counters + gather traces — required for the L2 cache model
    /// behind Table 3 and the Fig 4 roofline.
    Traces,
}

/// Which backend the builder instantiates. Kept as a spec (rather than a
/// built backend) so a builder can be shipped across threads — e.g. into
/// the serving dispatcher — and construct non-`Send` backends like PJRT
/// in place.
pub enum BackendSpec {
    /// Native Rust kernels; trace recording follows [`Profiling`].
    Native(NativeBackend),
    /// PJRT over an AOT artifact directory.
    Pjrt {
        /// Artifact directory containing `manifest.json`.
        root: PathBuf,
    },
    /// Any user-provided backend.
    Custom(Box<dyn ExecBackend + Send>),
}

impl std::fmt::Debug for BackendSpec {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            BackendSpec::Native(b) => f.debug_tuple("Native").field(b).finish(),
            BackendSpec::Pjrt { root } => f.debug_struct("Pjrt").field("root", root).finish(),
            BackendSpec::Custom(b) => f.debug_tuple("Custom").field(b).finish(),
        }
    }
}

impl Default for BackendSpec {
    fn default() -> Self {
        BackendSpec::Native(NativeBackend::default())
    }
}

impl From<NativeBackend> for BackendSpec {
    fn from(b: NativeBackend) -> Self {
        BackendSpec::Native(b)
    }
}

impl From<Box<dyn ExecBackend + Send>> for BackendSpec {
    fn from(b: Box<dyn ExecBackend + Send>) -> Self {
        BackendSpec::Custom(b)
    }
}

/// Everything one [`Session::run`] produces.
#[derive(Debug)]
pub struct SessionRun {
    /// Final embeddings of the plan's target node type.
    pub output: Tensor,
    /// Per-subgraph Neighbor Aggregation results (empty on whole-model
    /// backends, whose artifact fuses the stages).
    pub na_results: Vec<Tensor>,
    /// Kernel-level profile with modeled T4 metrics (empty on
    /// whole-model backends — profiling is a staged-backend capability).
    pub profile: Profile,
    /// Modeled schedule analysis.
    pub report: ScheduleReport,
    /// End-to-end wallclock of this run, nanoseconds.
    pub wall_nanos: u64,
}

/// Builder for [`Session`]. See the module docs for the canonical
/// incantation; every knob has a sensible default except the graph
/// source (`dataset` / `graph` / `plan` + `graph`).
#[derive(Debug, Default)]
pub struct SessionBuilder {
    dataset: Option<DatasetId>,
    scale: Option<DatasetScale>,
    graph: Option<HeteroGraph>,
    plan: Option<ModelPlan>,
    model: Option<ModelId>,
    config: ModelConfig,
    backend: BackendSpec,
    policy: SchedulePolicy,
    profiling: Profiling,
    gpu: Option<GpuModel>,
    sampling: Option<SamplingSpec>,
    reuse: Option<ReuseSpec>,
    partition: Option<PartitionSpec>,
    quantize: Option<QuantSpec>,
    threads: Option<usize>,
    dynamic: Option<DynamicSpec>,
    cluster: Option<ClusterSpec>,
    cluster_transport: Option<TransportSlot>,
}

/// Builder slot for a user-supplied cluster transport; the trait object
/// itself is not `Debug`, so the slot supplies a placeholder.
struct TransportSlot(Box<dyn Transport>);

impl std::fmt::Debug for TransportSlot {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str("Box<dyn Transport>")
    }
}

impl Default for SchedulePolicy {
    fn default() -> Self {
        SchedulePolicy::Sequential
    }
}

impl SessionBuilder {
    /// Synthesize this dataset as the session graph.
    pub fn dataset(mut self, id: DatasetId) -> Self {
        self.dataset = Some(id);
        self
    }

    /// Dataset scale (defaults to [`DatasetScale::paper`]).
    pub fn scale(mut self, scale: DatasetScale) -> Self {
        self.scale = Some(scale);
        self
    }

    /// Use an already-built graph instead of synthesizing one.
    pub fn graph(mut self, hg: HeteroGraph) -> Self {
        self.graph = Some(hg);
        self
    }

    /// Use an already-built plan (skips `model`/`config`-driven plan
    /// construction; the graph must still be provided).
    pub fn plan(mut self, plan: ModelPlan) -> Self {
        self.plan = Some(plan);
        self
    }

    /// Which model to plan (defaults to HAN).
    pub fn model(mut self, model: ModelId) -> Self {
        self.model = Some(model);
        self
    }

    /// Model hyper-parameters.
    pub fn config(mut self, config: ModelConfig) -> Self {
        self.config = config;
        self
    }

    /// Execution backend (defaults to [`NativeBackend`]).
    pub fn backend(mut self, spec: impl Into<BackendSpec>) -> Self {
        self.backend = spec.into();
        self
    }

    /// Sugar: PJRT backend over an artifact directory.
    pub fn pjrt(mut self, root: impl Into<PathBuf>) -> Self {
        self.backend = BackendSpec::Pjrt { root: root.into() };
        self
    }

    /// Schedule policy (defaults to [`SchedulePolicy::Sequential`]).
    pub fn schedule(mut self, policy: SchedulePolicy) -> Self {
        self.policy = policy;
        self
    }

    /// Profiling depth (defaults to [`Profiling::Counters`]).
    pub fn profiling(mut self, profiling: Profiling) -> Self {
        self.profiling = profiling;
        self
    }

    /// Override the GPU model (custom calibration experiments).
    pub fn gpu_model(mut self, gpu: GpuModel) -> Self {
        self.gpu = Some(gpu);
        self
    }

    /// Enable mini-batch metapath sampling for the batch/serving path:
    /// [`Session::run_batch`] executes the FP/NA/SA stages over a
    /// [`SampledSubgraph`] of the requested seeds instead of gathering
    /// rows from a cached full-graph forward, so batch latency scales
    /// with batch size rather than graph size. Whole-model backends
    /// (fused static-shape artifacts) ignore the spec and keep the
    /// cached full-graph path.
    pub fn sampling(mut self, spec: SamplingSpec) -> Self {
        self.sampling = Some(spec);
        self
    }

    /// Enable the cross-request reuse caches for the sampled batch path:
    /// [`Session::run_batch`] then memoizes stage-② projection rows per
    /// (type, node) and stage-③ aggregate rows per (metapath, node) —
    /// valid at full-fanout coverage — across batches, so overlapping
    /// request streams stop re-computing the dominant stages for the
    /// same nodes. Cached rows substitute bit-identically (see
    /// [`crate::reuse`]); capacities bound both caches with clock
    /// eviction, and weight/feature changes invalidate by generation
    /// ([`Session::invalidate`], [`Session::set_weights`]). Requires
    /// [`SessionBuilder::sampling`].
    pub fn reuse(mut self, spec: ReuseSpec) -> Self {
        self.reuse = Some(spec);
        self
    }

    /// Shard the session: the graph is split into `spec.shards`
    /// degree-balanced shards per node type
    /// ([`crate::partition::Partition::build`], cached here across every
    /// run and served batch). [`Session::run`] then executes FP/NA per
    /// shard on `spec.threads` worker-pool tasks with a halo feature exchange
    /// and an owner-computes merge — **bit-identical** to the monolithic
    /// forward. The partition subsumes the [`SchedulePolicy`] for that
    /// full forward (the report carries the effective
    /// inter-subgraph-parallel shape at the thread count).
    /// [`Session::run_batch`] (with [`SessionBuilder::sampling`]) splits
    /// each batch by seed owner and executes the shard-affine
    /// sub-batches concurrently — each against its own reuse-cache lane
    /// when [`SessionBuilder::reuse`] is stacked on top, so the lanes
    /// never contend (interior nodes sampled from several shards' seeds
    /// are cached per lane: bounded replication for lock-freedom).
    /// Whole-model backends ignore the spec (their fused artifact
    /// subsumes any partition).
    pub fn partition(mut self, spec: PartitionSpec) -> Self {
        self.partition = Some(spec);
        self
    }

    /// Distribute the sharded forward across a cluster of shard
    /// workers behind a message fabric (see [`crate::cluster`]): a
    /// coordinator places the partition's shards onto `spec.workers`
    /// workers, ships the FP/NA stage requests and halo blocks over the
    /// length-prefixed wire codec, and merges the owner-computes
    /// responses — **bit-identical** to the monolithic and sharded
    /// forwards. Without an explicit [`SessionBuilder::partition`] the
    /// session defaults to one shard per worker. The transport is the
    /// deterministic in-process [`SimTransport`] seeded from
    /// `spec.fault`, so every delivery, fault, timeout and re-placement
    /// reproduces exactly from the seed; use
    /// [`SessionBuilder::cluster_transport`] for a real wire. Worker
    /// death (scheduled via `spec`, or reported through
    /// [`Session::handle_worker_down`]) retires the worker, re-places
    /// its shards from the retained partition and replays the in-flight
    /// wave. Whole-model backends ignore the spec, like any partition.
    pub fn cluster(mut self, spec: ClusterSpec) -> Self {
        self.cluster = Some(spec);
        self
    }

    /// Like [`SessionBuilder::cluster`], but over a caller-supplied
    /// [`Transport`] — e.g. the Unix-socket-pair transport built with
    /// `--features cluster-sockets`, where every frame genuinely
    /// traverses the kernel.
    pub fn cluster_transport(
        mut self,
        spec: ClusterSpec,
        transport: Box<dyn Transport>,
    ) -> Self {
        self.cluster = Some(spec);
        self.cluster_transport = Some(TransportSlot(transport));
        self
    }

    /// Opt into the quantized feature-projection path: the plan's FP
    /// weight matrices are round-tripped through `spec`'s storage
    /// format (f16, or int8 with per-column scales) at build time and
    /// on every [`Session::set_weights`], and any reuse-cache rows
    /// ([`SessionBuilder::reuse`]) are stored quantized and dequantized
    /// on aggregate — shrinking weight and cache residency 2× (f16) or
    /// ~4× (int8). Off by default; outputs then differ from the f32
    /// session by the format's rounding error, so bit-identity
    /// guarantees (warm-vs-cold, quantized-vs-f32) no longer hold —
    /// quantify the drift with [`crate::report::quant_delta_table`].
    pub fn quantize(mut self, spec: QuantSpec) -> Self {
        self.quantize = Some(spec);
        self
    }

    /// Cap the process-wide worker pool at `n` threads (min 1) for
    /// everything this session executes — both the intra-kernel
    /// `parallel_for` inside `sgemm`/`SpMMCsr`/`IndexSelect` and the
    /// task-level NA/shard schedules, which share one pool (see
    /// [`crate::parallel`]). The cap is installed thread-locally around
    /// each run, so concurrent sessions with different `threads`
    /// settings never fight over a global. Default: the process default
    /// (`HGNN_THREADS` env var, else available parallelism). Parallel
    /// results are bit-identical to `threads(1)` at every setting.
    pub fn threads(mut self, n: usize) -> Self {
        self.threads = Some(n.max(1));
        self
    }

    /// Enable streaming graph updates with epoch-barrier snapshot
    /// serving (see [`crate::dynamic`]): [`Session::apply_updates`]
    /// buffers edge/node insertions and feature/weight updates in a
    /// bounded [`UpdateLog`] while every run and served batch keeps
    /// executing against the current immutable snapshot, and
    /// [`Session::flip_epoch`] atomically applies the pending log —
    /// re-deriving only the affected sub-CSRs, evicting only the touched
    /// reuse-cache keys, patching only the dirty partition shards and
    /// recomputing NA only for the touched destination rows. Post-flip
    /// outputs are bit-identical to a cold session built from the
    /// fully-applied graph.
    pub fn dynamic(mut self, spec: DynamicSpec) -> Self {
        self.dynamic = Some(spec);
        self
    }

    /// Build the session: synthesize/adopt the graph, build the plan,
    /// instantiate the backend.
    pub fn build(self) -> Result<Session> {
        let scale = self.scale.unwrap_or_else(DatasetScale::paper);
        let hg = match (self.graph, self.dataset) {
            (Some(hg), _) => hg,
            (None, Some(id)) => datasets::build(id, &scale)?,
            (None, None) => {
                return Err(Error::config(
                    "SessionBuilder needs a graph source: .dataset(..), .graph(..), \
                     or .plan(..) with .graph(..)",
                ))
            }
        };
        let mut plan = match self.plan {
            Some(plan) => plan,
            None => {
                let model = self.model.unwrap_or(ModelId::Han);
                models::build_plan(model, &hg, &self.config)?
            }
        };
        // fake-quantize the FP weights before the partition copies them,
        // so shard plans and the monolithic plan agree exactly
        if let Some(spec) = self.quantize {
            quantize_proj_weights(&mut plan.weights, spec);
        }
        let backend: Box<dyn ExecBackend> = match self.backend {
            BackendSpec::Native(native) => {
                // the profiling level can only *add* trace recording to a
                // user-configured native backend, never strip it
                let record =
                    native.record_traces || matches!(self.profiling, Profiling::Traces);
                Box::new(native.with_traces(record))
            }
            BackendSpec::Pjrt { root } => Box::new(PjrtBackend::new(root)?),
            BackendSpec::Custom(custom) => custom,
        };
        let scratch = backend.make_ctx();
        let sampler = match self.sampling {
            Some(spec) => Some(NeighborSampler::new(spec)?),
            None => None,
        };
        if self.reuse.is_some() && sampler.is_none() {
            return Err(Error::config(
                "SessionBuilder::reuse(..) requires .sampling(..): the reuse caches \
                 memoize sampled-batch stage results",
            ));
        }
        // a cluster without an explicit partition defaults to one
        // shard per worker — every worker owns exactly one shard
        let partition_spec = match (&self.cluster, self.partition) {
            (Some(cs), None) => Some(PartitionSpec::new(cs.workers.max(1))),
            (_, spec) => spec,
        };
        let partition = match partition_spec {
            Some(spec) => Some(Partition::build(&hg, &plan, &spec)?),
            None => None,
        };
        let cluster = match self.cluster {
            Some(spec) => {
                let shards =
                    partition.as_ref().map(|p| p.num_shards()).unwrap_or(spec.workers);
                let transport: Box<dyn Transport> = match self.cluster_transport {
                    Some(TransportSlot(t)) => t,
                    None => Box::new(SimTransport::faulty(spec.fault.clone())),
                };
                Some(Cluster::new(spec, shards, transport)?)
            }
            None => None,
        };
        // one reuse-cache lane per shard (each shard-affine sub-batch
        // touches only its own lane, so lanes never contend); one lane
        // when the session is unpartitioned
        let lanes = partition.as_ref().map(|p| p.num_shards()).unwrap_or(1);
        let reuse = self.reuse.map(|spec| {
            (0..lanes).map(|_| ReuseCache::with_quant(spec, self.quantize)).collect::<Vec<_>>()
        });
        let shard_scratch = (0..partition.as_ref().map(|p| p.num_shards()).unwrap_or(0))
            .map(|_| backend.make_ctx())
            .collect();
        Ok(Session {
            hg,
            plan,
            backend,
            gpu: self.gpu.unwrap_or_default(),
            policy: self.policy,
            profiling: self.profiling,
            sampler,
            reuse,
            partition,
            cluster,
            retired_reuse: ReuseStats::default(),
            quant: self.quantize,
            threads: self.threads,
            scratch,
            shard_scratch,
            cached_output: None,
            dynamic: self.dynamic.map(|spec| DynamicState {
                spec,
                log: UpdateLog::new(spec),
                epoch: 0,
                na_cache: None,
            }),
            runs: 0,
        })
    }

    /// Build the session *inside the serving dispatcher thread* and
    /// serve batched embedding requests through it. This is the one
    /// serving entry point: any backend (PJRT backends are constructed
    /// in-thread, which is what their non-`Send` internals require) ×
    /// any schedule policy, with the plan, weights and compiled
    /// artifacts reused across batches.
    pub fn serve(self, config: ServeConfig) -> Server {
        Server::start_session(config, self)
    }

    /// Like [`SessionBuilder::serve`], but through the async serving
    /// runtime: continuous batching, priority classes, deadlines and
    /// admission control, with typed [`ServeError`]s instead of silent
    /// unbounded queueing. The session is still built inside the
    /// dispatcher thread.
    pub fn serve_async(self, config: ServingConfig) -> AsyncServer {
        AsyncServer::start_session(config, self)
    }
}

/// A session: the single execution surface over backend × schedule ×
/// profiling. Owns the graph, plan, GPU model and all cached state.
#[derive(Debug)]
pub struct Session {
    hg: HeteroGraph,
    plan: ModelPlan,
    backend: Box<dyn ExecBackend>,
    gpu: GpuModel,
    policy: SchedulePolicy,
    profiling: Profiling,
    /// Mini-batch sampler cached by the builder; `Some` switches
    /// [`Session::run_batch`] to sampled-subgraph execution.
    sampler: Option<NeighborSampler>,
    /// Cross-request reuse caches shared across every batch this session
    /// (and hence a serving dispatcher) executes — one lane per shard
    /// when the session is partitioned, else one.
    reuse: Option<Vec<ReuseCache>>,
    /// Degree-balanced K-way partition cached by the builder; `Some`
    /// switches [`Session::run`] to sharded execution and
    /// [`Session::run_batch`] to shard-affine sub-batches.
    partition: Option<Partition>,
    /// Distributed-execution coordinator ([`SessionBuilder::cluster`]):
    /// owns shard placement, the failure detector and the wire
    /// protocol. `Some` switches [`Session::run`] to
    /// [`exec::execute_distributed`] and [`Session::run_batch`] to the
    /// cluster batch round.
    cluster: Option<Cluster>,
    /// Reuse counters absorbed from cache lanes rebuilt after worker
    /// re-placement, so [`Session::reuse_stats`] stays cumulative —
    /// and never double-counts a dead lane — across kill/re-place
    /// cycles.
    retired_reuse: ReuseStats,
    /// Quantized feature-projection format
    /// ([`SessionBuilder::quantize`]): FP weights are round-tripped
    /// through this format on every swap and reuse-cache rows are
    /// stored in it. `None` keeps the default all-f32 path.
    quant: Option<QuantSpec>,
    /// Worker-pool cap installed (thread-locally) around every run;
    /// `None` inherits the process default.
    threads: Option<usize>,
    /// Kernel context reused across runs (event-buffer allocation
    /// survives between runs).
    scratch: Ctx,
    /// One persistent kernel context per shard for the shard-affine
    /// batch path ([`Session::run_batch`] on a partitioned session), so
    /// concurrent sub-batches keep their own scratch arenas across
    /// dispatches instead of rebuilding a context per task. Empty when
    /// the session is unpartitioned.
    shard_scratch: Vec<Ctx>,
    /// Last full-graph embeddings, reused by [`Session::run_batch`].
    cached_output: Option<Tensor>,
    /// Streaming-update state ([`SessionBuilder::dynamic`]): the pending
    /// log, the epoch counter and the materialized per-subgraph NA
    /// results the epoch flip patches incrementally. `None` disables
    /// [`Session::apply_updates`] / [`Session::flip_epoch`].
    dynamic: Option<DynamicState>,
    runs: u64,
}

/// Per-session dynamic-graph state (see [`crate::dynamic`]).
#[derive(Debug)]
struct DynamicState {
    spec: DynamicSpec,
    log: UpdateLog,
    epoch: u64,
    /// Per-subgraph NA results of the last *full-graph* staged run —
    /// the tensor bank [`exec::execute_patch`] splices touched rows
    /// into at each flip. `None` until a full run materializes it, and
    /// after any weight swap (weights couple every row).
    na_cache: Option<Vec<Tensor>>,
}

/// Round-trip the FP projection weights through `spec`'s storage format
/// in place (fake quantization): the working copies every compute path
/// consumes — including the packed sgemm panels derived from them — are
/// exactly the dequantized values, so the f32 kernels, counters and
/// event stream stay untouched while the numerics match a genuinely
/// quantized weight store.
fn quantize_proj_weights(weights: &mut ModelWeights, spec: QuantSpec) {
    for w in weights.proj.values_mut() {
        *w = QuantMatrix::quantize(w, spec).dequantize();
    }
}

impl Session {
    /// Start building a session.
    pub fn builder() -> SessionBuilder {
        SessionBuilder::default()
    }

    /// The session graph.
    pub fn graph(&self) -> &HeteroGraph {
        &self.hg
    }

    /// The session plan.
    pub fn plan(&self) -> &ModelPlan {
        &self.plan
    }

    /// The backend's short name.
    pub fn backend_name(&self) -> &'static str {
        self.backend.name()
    }

    /// The backend's capability flags.
    pub fn backend_caps(&self) -> BackendCaps {
        self.backend.caps()
    }

    /// The schedule policy in effect.
    pub fn policy(&self) -> SchedulePolicy {
        self.policy
    }

    /// The profiling depth in effect.
    pub fn profiling(&self) -> Profiling {
        self.profiling
    }

    /// The GPU model in use.
    pub fn gpu_model(&self) -> &GpuModel {
        &self.gpu
    }

    /// Completed run count (runs + batch-triggered runs).
    pub fn runs(&self) -> u64 {
        self.runs
    }

    /// Swap the schedule policy between runs (the compiled/cached state
    /// is schedule-independent, so nothing is invalidated).
    pub fn set_schedule(&mut self, policy: SchedulePolicy) {
        self.policy = policy;
    }

    /// The worker-pool cap this session installs around its runs
    /// ([`SessionBuilder::threads`]); `None` inherits the process
    /// default.
    pub fn threads(&self) -> Option<usize> {
        self.threads
    }

    /// Counter snapshot of the session's scratch arenas (the reusable
    /// buffer pools behind steady-state zero-allocation dispatches),
    /// aggregated across the per-shard contexts on a partitioned
    /// session.
    pub fn arena_stats(&self) -> crate::kernels::ArenaStats {
        let mut total = self.scratch.arena.stats();
        for ctx in &self.shard_scratch {
            let s = ctx.arena.stats();
            total.hits += s.hits;
            total.misses += s.misses;
            total.held += s.held;
        }
        total
    }

    /// Run `f` under this session's worker-pool cap (no-op wrapper when
    /// the session has no explicit cap).
    fn with_pool<R>(threads: Option<usize>, f: impl FnOnce() -> R) -> R {
        match threads {
            Some(t) => crate::parallel::with_threads(t, f),
            None => f(),
        }
    }

    /// Run inference under the session policy.
    ///
    /// Whole-model backends (`caps().whole_model`) execute their fused
    /// artifact — the artifact's internal schedule subsumes the policy —
    /// and report an empty kernel profile; staged backends run the full
    /// scheduler with per-kernel attribution.
    pub fn run(&mut self) -> Result<SessionRun> {
        let threads = self.threads;
        Self::with_pool(threads, || self.run_unscoped())
    }

    fn run_unscoped(&mut self) -> Result<SessionRun> {
        let t0 = Instant::now();
        let run = if self.backend.caps().whole_model {
            match self.backend.run_full(&self.plan, &self.hg)? {
                Some(output) => {
                    let profile = Profile::default();
                    let report =
                        schedule::analyze(&profile, 1, false, self.policy, &self.gpu);
                    StagedRun { output, na_results: Vec::new(), profile, report }
                }
                None => self.run_staged()?,
            }
        } else {
            self.run_staged()?
        };
        self.runs += 1;
        if let Some(state) = self.dynamic.as_mut() {
            // materialize the NA bank the epoch flip patches; whole-model
            // backends return no per-stage results, so flips there fall
            // back to dropping the cached output
            state.na_cache = (run.na_results.len() == self.plan.num_subgraphs()
                && !run.na_results.is_empty())
            .then(|| run.na_results.clone());
        }
        Ok(SessionRun {
            output: run.output,
            na_results: run.na_results,
            profile: run.profile,
            report: run.report,
            wall_nanos: t0.elapsed().as_nanos() as u64,
        })
    }

    fn run_staged(&mut self) -> Result<StagedRun> {
        // field-disjoint borrows: the cluster (mutable, drives the wire
        // protocol) alongside the partition, backend, plan and scratch
        let Session { backend, gpu, plan, hg, partition, cluster, scratch, policy, .. } =
            self;
        let run = match (partition.as_ref(), cluster.as_mut()) {
            (Some(part), Some(cl)) => exec::execute_distributed(
                backend.as_ref(),
                gpu,
                plan,
                hg,
                part,
                cl,
                scratch,
            )?,
            (Some(part), None) => {
                exec::execute_sharded(backend.as_ref(), gpu, plan, hg, part, scratch)?
            }
            (None, _) => {
                exec::execute(backend.as_ref(), gpu, plan, hg, *policy, scratch)?
            }
        };
        // worker deaths during the wave re-placed shards: rebuild their
        // reuse-cache lanes cold before the next batch reads them
        self.sync_cluster_lanes();
        Ok(run)
    }

    /// The cached partition, if the session is sharded.
    pub fn partition(&self) -> Option<&Partition> {
        self.partition.as_ref()
    }

    /// Owning shard of a target-type node id (wraps like
    /// [`Session::run_batch`]); `None` when the session is unpartitioned.
    pub fn shard_of(&self, node_id: u32) -> Option<usize> {
        let part = self.partition.as_ref()?;
        let n = self.hg.node_type(self.plan.target).count.max(1) as u32;
        Some(part.owner_of(self.plan.target, node_id % n))
    }

    /// Run only FP + NA (the Fig 5a/5b sweeps time NA in isolation).
    pub fn run_na_only(&mut self) -> Result<(Vec<Tensor>, Profile)> {
        let threads = self.threads;
        let out = Self::with_pool(threads, || {
            exec::run_na_only(
                self.backend.as_ref(),
                &self.gpu,
                &self.plan,
                &self.hg,
                &mut self.scratch,
            )
        })?;
        self.runs += 1;
        Ok(out)
    }

    /// The sampling spec in effect, if mini-batch sampling is enabled.
    pub fn sampling(&self) -> Option<&SamplingSpec> {
        self.sampler.as_ref().map(|s| s.spec())
    }

    /// Embedding rows for a batch of target node ids; ids wrap modulo
    /// the target-type node count, as the serving path has always done.
    ///
    /// Without [`SessionBuilder::sampling`], the full-graph forward runs
    /// (at most) once and its output is cached (moved, not cloned) and
    /// reused across batches until [`Session::invalidate`]. Plain
    /// [`Session::run`] calls do not touch this cache — the cost of
    /// caching is paid only by the batch path that reads it.
    ///
    /// With sampling enabled (and a staged backend), every call samples
    /// the batch's metapath neighborhood and executes the FP/NA/SA
    /// stages over that [`SampledSubgraph`] only — embeddings are always
    /// fresh and the cost scales with the batch, not the graph.
    /// Whole-model backends keep the cached full-graph path: their fused
    /// static-shape artifact subsumes any subgraph schedule.
    pub fn run_batch(&mut self, node_ids: &[u32]) -> Result<Vec<Vec<f32>>> {
        if self.sampler.is_some() && !self.backend.caps().whole_model {
            let threads = self.threads;
            return Self::with_pool(threads, || self.run_batch_sampled(node_ids));
        }
        if self.cached_output.is_none() {
            let run = self.run()?;
            self.cached_output = Some(run.output);
        }
        let z = self.cached_output.as_ref().expect("populated above");
        let n = z.rows().max(1);
        Ok(node_ids.iter().map(|&i| z.row(i as usize % n).to_vec()).collect())
    }

    /// Sample the mini-batch neighborhood of `node_ids` without
    /// executing it (ids wrap like [`Session::run_batch`]). Errors when
    /// the session was built without [`SessionBuilder::sampling`].
    pub fn sample_batch(&self, node_ids: &[u32]) -> Result<SampledSubgraph> {
        let sampler = self.sampler.as_ref().ok_or_else(|| {
            Error::config("session built without .sampling(..); nothing to sample")
        })?;
        sampler.sample(&self.hg, &self.plan, &self.wrap_ids(node_ids))
    }

    /// Map requested ids onto target-type node ids (wrap modulo the
    /// node count — the serving path's long-standing id semantics).
    fn wrap_ids(&self, node_ids: &[u32]) -> Vec<u32> {
        let n = self.hg.node_type(self.plan.target).count.max(1) as u32;
        node_ids.iter().map(|&i| i % n).collect()
    }

    /// The sampled batch path: one sampled subgraph per call, executed
    /// through the ordinary [`ExecBackend`] stage entry points — with
    /// the reuse caches threaded through sampling and execution when
    /// [`SessionBuilder::reuse`] configured them. On a partitioned
    /// session the batch first splits by seed owner
    /// ([`Session::run_batch_shard_affine`]).
    fn run_batch_sampled(&mut self, node_ids: &[u32]) -> Result<Vec<Vec<f32>>> {
        let seeds = self.wrap_ids(node_ids);
        if self.cluster.is_some() {
            return self.run_batch_cluster(&seeds);
        }
        if self.partition.as_ref().is_some_and(|p| p.num_shards() > 1) {
            return self.run_batch_shard_affine(&seeds);
        }
        // field-disjoint borrows: sampler (shared) alongside the reuse
        // cache (mutable) — no per-batch clone on the serving hot path
        let sampler = self.sampler.as_ref().expect("checked by run_batch");
        let (sampled, run) = match self.reuse.as_mut().map(|lanes| &mut lanes[0]) {
            Some(cache) => {
                let sampled =
                    sampler.sample_with_cache(&self.hg, &self.plan, &seeds, cache)?;
                let run = exec::execute_reuse(
                    self.backend.as_ref(),
                    &self.gpu,
                    &sampled,
                    self.policy,
                    &mut self.scratch,
                    cache,
                )?;
                (sampled, run)
            }
            None => {
                let sampled = sampler.sample(&self.hg, &self.plan, &seeds)?;
                let run = exec::execute(
                    self.backend.as_ref(),
                    &self.gpu,
                    &sampled.plan,
                    &sampled.graph,
                    self.policy,
                    &mut self.scratch,
                )?;
                (sampled, run)
            }
        };
        self.runs += 1;
        // seed j is local row seed_rows[j] of the executed output;
        // duplicate ids in the batch collapse onto the same seed row
        let row_of = sampled.seed_row_map();
        let mut out = Vec::with_capacity(seeds.len());
        for g in &seeds {
            let j = *row_of
                .get(g)
                .ok_or_else(|| Error::config(format!("seed {g} lost in sampling")))?;
            out.push(run.output.row(j).to_vec());
        }
        self.recycle_run(run);
        Ok(out)
    }

    /// Park a finished batch-run's stage outputs in the scratch arena so
    /// the next dispatch checks them out instead of allocating — the
    /// serving half of the steady-state zero-allocation contract.
    fn recycle_run(&mut self, run: exec::StagedRun) {
        self.scratch.arena.give(run.output.into_vec());
        for t in run.na_results {
            self.scratch.arena.give(t.into_vec());
        }
    }

    /// The shard-affine batch path: split the (wrapped) seeds by owner
    /// shard, sample and execute each non-empty sub-batch — concurrently
    /// on worker-pool tasks when the backend is thread-safe — each against
    /// its shard's own reuse-cache lane (contention-free because a
    /// sub-batch only ever touches its seed-owner's lane; interior nodes
    /// reached from several shards' seeds are cached per lane), then
    /// reassemble rows in request order. Each sub-batch executes exactly
    /// as an unpartitioned session would execute it, so per-sub-batch
    /// results are bit-identical to the monolithic sampled path.
    fn run_batch_shard_affine(&mut self, seeds: &[u32]) -> Result<Vec<Vec<f32>>> {
        let part = self.partition.as_ref().expect("checked by run_batch_sampled");
        let sampler = self.sampler.as_ref().expect("checked by run_batch");
        let k = part.num_shards();
        let target = self.plan.target;
        let mut groups: Vec<Vec<u32>> = vec![Vec::new(); k];
        for &g in seeds {
            groups[part.owner_of(target, g)].push(g);
        }
        // one mutable cache lane per shard, moved into its task
        let mut lanes: Vec<Option<&mut ReuseCache>> = match self.reuse.as_mut() {
            Some(v) => v.iter_mut().map(Some).collect(),
            None => (0..k).map(|_| None).collect(),
        };
        let hg = &self.hg;
        let plan = &self.plan;
        let gpu = &self.gpu;
        let policy = self.policy;
        let backend = self.backend.as_ref();
        struct ShardWork<'a> {
            group: &'a [u32],
            cache: Option<&'a mut ReuseCache>,
            scratch: &'a mut Ctx,
        }
        let mut work: Vec<ShardWork<'_>> = Vec::new();
        for (s, (lane, ctx)) in
            lanes.iter_mut().zip(self.shard_scratch.iter_mut()).enumerate()
        {
            if !groups[s].is_empty() {
                work.push(ShardWork {
                    group: groups[s].as_slice(),
                    cache: lane.take(),
                    scratch: ctx,
                });
            }
        }
        let results: Vec<Vec<(u32, Vec<f32>)>> = match self.backend.as_sync() {
            // concurrent sub-batches run as tasks on the shared worker
            // pool (their kernels inline — the pool's nesting rule);
            // each task takes its own mutable work item through a lock
            Some(sync) if work.len() > 1 => {
                let tasks: Vec<std::sync::Mutex<ShardWork<'_>>> =
                    work.into_iter().map(std::sync::Mutex::new).collect();
                crate::parallel::parallel_map(tasks.len(), |j| {
                    let mut guard = tasks[j].lock().unwrap_or_else(|e| e.into_inner());
                    let item: &mut ShardWork<'_> = &mut guard;
                    let group = item.group;
                    let cache = item.cache.take();
                    shard_batch_task(
                        &SyncAsExec(sync),
                        hg,
                        plan,
                        gpu,
                        policy,
                        sampler,
                        group,
                        cache,
                        &mut *item.scratch,
                    )
                })
                .into_iter()
                .collect::<Result<Vec<_>>>()?
            }
            _ => work
                .into_iter()
                .map(|item| {
                    shard_batch_task(
                        backend,
                        hg,
                        plan,
                        gpu,
                        policy,
                        sampler,
                        item.group,
                        item.cache,
                        item.scratch,
                    )
                })
                .collect::<Result<Vec<_>>>()?,
        };
        self.runs += 1;
        let mut row_of: std::collections::HashMap<u32, Vec<f32>> =
            std::collections::HashMap::with_capacity(seeds.len());
        for (g, row) in results.into_iter().flatten() {
            row_of.insert(g, row);
        }
        // move each row out on its first use; only duplicate ids in the
        // batch (which share one seed row) pay a copy
        let mut first_at: std::collections::HashMap<u32, usize> =
            std::collections::HashMap::with_capacity(seeds.len());
        let mut out: Vec<Vec<f32>> = Vec::with_capacity(seeds.len());
        for &g in seeds {
            if let Some(row) = row_of.remove(&g) {
                first_at.insert(g, out.len());
                out.push(row);
            } else if let Some(&j) = first_at.get(&g) {
                let row = out[j].clone();
                out.push(row);
            } else {
                return Err(Error::config(format!("seed {g} lost in sharded batch")));
            }
        }
        Ok(out)
    }

    /// The cluster batch round: split the (wrapped) seeds by owner
    /// shard and run **one wave** of the wire protocol — the
    /// coordinator ships each non-empty group to its shard's worker as
    /// a `BatchRows` request, the worker samples and executes the
    /// sub-batch exactly as [`Session::run_batch_shard_affine`] would
    /// (through the shard's reuse-cache lane), and the embedding rows
    /// come back as a `BatchRows` block. Worker death mid-wave replays
    /// the lost sub-batches on the re-placement target, so replies stay
    /// bit-identical to the no-fault run.
    fn run_batch_cluster(&mut self, seeds: &[u32]) -> Result<Vec<Vec<f32>>> {
        // field-disjoint borrows: the cluster (mutable) alongside the
        // partition, sampler, reuse lanes and per-shard scratch
        let Session {
            hg,
            plan,
            backend,
            gpu,
            policy,
            sampler,
            reuse,
            partition,
            shard_scratch,
            cluster,
            ..
        } = self;
        let part = partition.as_ref().expect("cluster sessions are always partitioned");
        let cluster = cluster.as_mut().expect("checked by run_batch_sampled");
        let sampler = sampler.as_ref().expect("checked by run_batch");
        let backend = backend.as_ref();
        let policy = *policy;
        let k = part.num_shards();
        let target = plan.target;
        let mut groups: Vec<Vec<u32>> = vec![Vec::new(); k];
        for &g in seeds {
            groups[part.owner_of(target, g)].push(g);
        }
        let mut lanes: Vec<Option<&mut ReuseCache>> = match reuse.as_mut() {
            Some(v) => v.iter_mut().map(Some).collect(),
            None => (0..k).map(|_| None).collect(),
        };
        let mut scratches: Vec<&mut Ctx> = shard_scratch.iter_mut().collect();
        cluster.begin_wave()?;
        let replies = cluster.stage_round(
            k,
            &mut |s| {
                if groups[s].is_empty() {
                    return Ok(Vec::new());
                }
                Ok(vec![Message::BatchRows {
                    shard: s as u32,
                    block: RowBlock::ids_only(groups[s].clone()),
                }])
            },
            &mut |s, msgs| {
                let ids = match msgs.first() {
                    Some(Message::BatchRows { block, .. }) => block.ids.clone(),
                    other => {
                        return Err(Error::config(format!(
                            "cluster batch: shard {s} received malformed request {other:?}"
                        )))
                    }
                };
                let rows = shard_batch_task(
                    backend,
                    hg,
                    plan,
                    gpu,
                    policy,
                    sampler,
                    &ids,
                    lanes[s].as_deref_mut(),
                    &mut *scratches[s],
                )?;
                let cols = rows.first().map(|(_, r)| r.len()).unwrap_or(0) as u32;
                let mut block = RowBlock {
                    ids: Vec::with_capacity(rows.len()),
                    cols,
                    data: Vec::with_capacity(rows.len() * cols as usize),
                };
                for (g, row) in rows {
                    block.ids.push(g);
                    block.data.extend_from_slice(&row);
                }
                Ok(vec![Message::BatchRows { shard: s as u32, block }])
            },
            &|s| usize::from(!groups[s].is_empty()),
        )?;
        let mut row_of: std::collections::HashMap<u32, Vec<f32>> =
            std::collections::HashMap::with_capacity(seeds.len());
        for msgs in &replies {
            for m in msgs {
                if let Message::BatchRows { block, .. } = m {
                    let cols = block.cols as usize;
                    for (i, &g) in block.ids.iter().enumerate() {
                        row_of.insert(g, block.data[i * cols..(i + 1) * cols].to_vec());
                    }
                }
            }
        }
        self.runs += 1;
        self.sync_cluster_lanes();
        // move each row out on its first use; only duplicate ids in the
        // batch (which share one seed row) pay a copy
        let mut first_at: std::collections::HashMap<u32, usize> =
            std::collections::HashMap::with_capacity(seeds.len());
        let mut out: Vec<Vec<f32>> = Vec::with_capacity(seeds.len());
        for &g in seeds {
            if let Some(row) = row_of.remove(&g) {
                first_at.insert(g, out.len());
                out.push(row);
            } else if let Some(&j) = first_at.get(&g) {
                let row = out[j].clone();
                out.push(row);
            } else {
                return Err(Error::config(format!("seed {g} lost in cluster batch")));
            }
        }
        Ok(out)
    }

    /// The cluster coordinator, if distributed execution is enabled.
    pub fn cluster(&self) -> Option<&Cluster> {
        self.cluster.as_ref()
    }

    /// Mutable cluster access — tests and harnesses drive kill/drain
    /// schedules and idle protocol iterations through it.
    pub fn cluster_mut(&mut self) -> Option<&mut Cluster> {
        self.cluster.as_mut()
    }

    /// Cluster event counters (waves, retirements, re-placements,
    /// heartbeats, retransmits), if distributed execution is enabled.
    /// Deterministic under the simulated transport.
    pub fn cluster_stats(&self) -> Option<ClusterStats> {
        self.cluster.as_ref().map(|c| c.stats())
    }

    /// Report a worker as dead — the serving runtime routes worker-loss
    /// control events here between waves. The worker is killed and
    /// retired immediately (no heartbeat-timeout wait), its shards are
    /// re-placed onto the least-loaded live workers from the retained
    /// partition, and the moved shards' reuse-cache lanes are rebuilt
    /// cold (their counters absorbed into the cumulative totals
    /// exactly once). Returns the number of shards moved. Errors when
    /// the session has no cluster or `worker` is the last one standing.
    pub fn handle_worker_down(&mut self, worker: usize) -> Result<usize> {
        let cluster = self.cluster.as_mut().ok_or_else(|| {
            Error::config("Session built without .cluster(..): no workers to retire")
        })?;
        cluster.kill_worker(worker);
        let moved = cluster.retire_worker(worker)?.len();
        self.sync_cluster_lanes();
        Ok(moved)
    }

    /// Drain the cluster's re-placement log and rebuild the reuse-cache
    /// lane of every moved shard cold — the dead worker's lane state
    /// died with the worker. Each retired lane's counters are absorbed
    /// into [`Session::reuse_stats`]'s retired total exactly once, so
    /// the cumulative counters stay monotonic without double-counting
    /// the dead lane against its fresh replacement.
    fn sync_cluster_lanes(&mut self) {
        let Some(cluster) = self.cluster.as_mut() else { return };
        let moved = cluster.take_replacements();
        if moved.is_empty() {
            return;
        }
        if let Some(lanes) = self.reuse.as_mut() {
            for s in moved {
                if let Some(lane) = lanes.get_mut(s) {
                    self.retired_reuse.absorb(lane.stats());
                    *lane = ReuseCache::with_quant(lane.spec(), lane.quant());
                }
            }
        }
    }

    /// The reuse-cache capacities in effect, if cross-request reuse is
    /// enabled (per cache lane — a partitioned session keeps one lane
    /// per shard).
    pub fn reuse_spec(&self) -> Option<ReuseSpec> {
        self.reuse.as_ref().map(|lanes| lanes[0].spec())
    }

    /// Snapshot of the cumulative reuse-cache counters, if cross-request
    /// reuse is enabled — aggregated across the per-shard lanes on a
    /// partitioned session, plus the counters of lanes retired by
    /// cluster worker re-placement (absorbed exactly once when the lane
    /// was rebuilt cold, so a re-placed shard's fresh lane never
    /// double-counts its dead predecessor).
    pub fn reuse_stats(&self) -> Option<ReuseStats> {
        let lanes = self.reuse.as_ref()?;
        let mut total = self.retired_reuse.clone();
        for lane in lanes {
            total.absorb(lane.stats());
        }
        Some(total)
    }

    /// Per-lane reuse-cache counters (one entry per shard lane), if
    /// cross-request reuse is enabled. The serving runtime surfaces
    /// these so lane-level cache imbalance stays visible alongside the
    /// aggregated [`Session::reuse_stats`].
    pub fn reuse_lane_stats(&self) -> Option<Vec<ReuseStats>> {
        self.reuse
            .as_ref()
            .map(|lanes| lanes.iter().map(|l| l.stats().clone()).collect())
    }

    /// A `Send + Sync` snapshot of target-type shard ownership, if the
    /// session is partitioned. The async serving runtime publishes it
    /// from the dispatcher thread so submissions can be accounted (and
    /// shed) per shard lane before they ever reach the executor.
    pub fn shard_map(&self) -> Option<crate::partition::ShardMap> {
        self.partition.as_ref().map(|p| p.shard_map(self.plan.target))
    }

    /// Drop the cached embeddings and invalidate the reuse caches with a
    /// generation bump (e.g. after a feature-store refresh); the next
    /// [`Session::run_batch`] recomputes from scratch. Also drops every
    /// packed sgemm B-panel ([`crate::kernels::dense::PackCache`]) held
    /// by the session's kernel contexts, so no panel packed under the
    /// old weights can outlive them (the pack cache's own content
    /// fingerprint is the second line of defense).
    pub fn invalidate(&mut self) {
        self.cached_output = None;
        if let Some(lanes) = self.reuse.as_mut() {
            for lane in lanes {
                lane.invalidate();
            }
        }
        self.scratch.packs.clear();
        for ctx in &mut self.shard_scratch {
            ctx.packs.clear();
        }
    }

    /// The quantized feature-projection format in effect, if any
    /// ([`SessionBuilder::quantize`]).
    pub fn quantize(&self) -> Option<QuantSpec> {
        self.quant
    }

    /// Number of packed sgemm B-panels currently resident across the
    /// session's kernel contexts — observability for the panel-reuse
    /// tier (and the invalidation tests: [`Session::set_weights`] must
    /// drop this to zero).
    pub fn packed_panels(&self) -> usize {
        self.scratch.packs.len()
            + self.shard_scratch.iter().map(|c| c.packs.len()).sum::<usize>()
    }

    /// Replace the plan's weights (e.g. after a training refresh) and
    /// invalidate everything computed under the old ones: the cached
    /// full-graph embeddings and — via a generation bump — every reuse
    /// cache entry, so stale stage results can never leak into
    /// post-reload batches.
    ///
    /// The replacement must be a drop-in parameter swap (same model /
    /// config / graph shapes); an incompatible set is rejected here with
    /// a config error instead of surfacing later as an opaque shape
    /// error inside every served batch.
    pub fn set_weights(&mut self, weights: ModelWeights) -> Result<()> {
        let old = &self.plan.weights;
        let proj_ok = weights.proj.len() == old.proj.len()
            && weights
                .proj
                .iter()
                .all(|(ty, w)| old.proj.get(ty).map(|o| o.shape()) == Some(w.shape()));
        let embed_ok = weights.embed.len() == old.embed.len()
            && weights
                .embed
                .iter()
                .all(|(ty, e)| old.embed.get(ty).map(|o| o.shape()) == Some(e.shape()));
        let attn_ok = weights.attn_l.len() == old.attn_l.len()
            && weights.attn_r.len() == old.attn_r.len()
            && weights.attn_l.iter().zip(&old.attn_l).all(|(a, b)| a.len() == b.len())
            && weights.attn_r.iter().zip(&old.attn_r).all(|(a, b)| a.len() == b.len());
        let sem_ok = weights.sem_w.as_ref().map(|t| t.shape())
            == old.sem_w.as_ref().map(|t| t.shape());
        if !(proj_ok && embed_ok && attn_ok && sem_ok) {
            return Err(Error::config(
                "set_weights: replacement weights are not shape-compatible with the \
                 plan (build them from the same model, config and graph)",
            ));
        }
        self.plan.weights = weights;
        if let Some(spec) = self.quant {
            // keep the quantization invariant across swaps: training
            // steps and weight reloads land in the same storage format
            // the session was built with, before any shard plan copies
            quantize_proj_weights(&mut self.plan.weights, spec);
        }
        if let Some(part) = self.partition.as_mut() {
            // shard plans carry their own weight copies (R-GCN embedding
            // tables sliced to local rows) — re-derive them so no shard
            // ever aggregates under stale parameters
            part.refresh_weights(&self.plan);
        }
        self.invalidate();
        if let Some(state) = self.dynamic.as_mut() {
            // every NA row is a function of the weights: the flip's
            // splice bank is unusable until the next full run
            state.na_cache = None;
        }
        Ok(())
    }

    /// Re-initialize the plan's weights deterministically from a seed
    /// (the same PCG streams as plan construction, so two sessions
    /// seeded alike start bit-identical). Routed through
    /// [`Session::set_weights`], so cached outputs and reuse lanes
    /// invalidate like any other weight swap.
    pub fn init_weights(&mut self, seed: u64) -> Result<()> {
        let config = ModelConfig { seed, ..self.plan.config.clone() };
        let weights =
            ModelWeights::init(self.plan.model, &self.hg, &self.plan.subgraphs, &config);
        self.set_weights(weights)
    }

    /// Build a [`Trainer`] for this session's model (validates the
    /// config and seeds the classifier head + optimizer state).
    pub fn trainer(&self, config: TrainConfig) -> Result<Trainer> {
        Trainer::new(config, &self.plan.weights, self.plan.config.hidden_dim)
    }

    /// Run one mini-batch training epoch under the session's worker-pool
    /// cap: a seeded shuffle of the target nodes, chunked into batches;
    /// each batch runs forward (through the [`NeighborSampler`] when the
    /// session has one, full-graph otherwise), softmax cross-entropy
    /// over the trainer's classifier head, the staged backward
    /// (fused per [`TrainConfig::fused`]), and an optimizer step applied
    /// via [`Session::set_weights`] — so the reuse caches invalidate
    /// exactly as on any weight swap. Loss/accuracy are measured before
    /// each step.
    pub fn train_epoch(&mut self, tr: &mut Trainer) -> Result<EpochStats> {
        let threads = self.threads;
        Self::with_pool(threads, || self.train_epoch_unscoped(tr))
    }

    fn train_epoch_unscoped(&mut self, tr: &mut Trainer) -> Result<EpochStats> {
        let t0 = Instant::now();
        // training events are counted per batch, never drained into a
        // profile — drop the previous epoch's so scratch stays bounded
        self.scratch.events.clear();
        let cfg = tr.config().clone();
        let count = self.hg.node_type(self.plan.target).count;
        if count == 0 {
            return Err(Error::config("train: target type has no nodes"));
        }
        let mut order: Vec<u32> = (0..count as u32).collect();
        Pcg32::new(cfg.seed, 0x8000 + tr.epoch() as u64).shuffle(&mut order);
        let bsz = cfg.batch.min(count);

        let mut loss_sum = 0.0f64;
        let mut acc_sum = 0.0f64;
        let mut examples = 0usize;
        let mut batches = 0usize;
        let mut dispatches = 0usize;

        for batch in order.chunks(bsz) {
            // forward + loss + staged backward under field-disjoint
            // borrows; the optimizer step below needs `self` whole again
            let (loss, acc, n, disp, full_grads, head_grad) = {
                let Session { backend, plan, hg, sampler, scratch, .. } = &mut *self;
                match sampler.as_ref() {
                    Some(sampler) => {
                        let sampled = sampler.sample(hg, plan, batch)?;
                        let labels: Vec<u32> = sampled
                            .seeds
                            .iter()
                            .map(|&g| train::synthetic_label(cfg.seed, g, cfg.classes))
                            .collect();
                        let res = train::run_batch(
                            backend.as_ref(),
                            scratch,
                            &sampled.plan,
                            &sampled.graph,
                            tr.head(),
                            &sampled.seed_rows,
                            &labels,
                            cfg.fused,
                        )?;
                        // batch gradients are shaped like the sampled
                        // plan (embedding rows are batch-local): scatter
                        // them onto full-model shapes for the optimizer
                        let mut full = plan.weights.zeros_like();
                        train::fold_grads(&mut full, &res.grads.weights, Some(&sampled.nodes))?;
                        (
                            res.loss,
                            res.accuracy,
                            res.examples,
                            res.backward_dispatches,
                            full,
                            res.head_grad,
                        )
                    }
                    None => {
                        let labels: Vec<u32> = batch
                            .iter()
                            .map(|&g| train::synthetic_label(cfg.seed, g, cfg.classes))
                            .collect();
                        let res = train::run_batch(
                            backend.as_ref(),
                            scratch,
                            plan,
                            hg,
                            tr.head(),
                            batch,
                            &labels,
                            cfg.fused,
                        )?;
                        (
                            res.loss,
                            res.accuracy,
                            res.examples,
                            res.backward_dispatches,
                            res.grads.weights,
                            res.head_grad,
                        )
                    }
                }
            };

            let mut new_w = self.plan.weights.clone();
            {
                let Trainer { head, opt, .. } = tr;
                opt.step(&mut new_w, head, &full_grads, &head_grad)?;
            }
            self.set_weights(new_w)?;

            loss_sum += loss * n as f64;
            acc_sum += acc * n as f64;
            examples += n;
            batches += 1;
            dispatches += disp;
        }

        tr.epoch += 1;
        Ok(EpochStats {
            epoch: tr.epoch,
            loss: loss_sum / examples as f64,
            accuracy: acc_sum / examples as f64,
            batches,
            examples,
            backward_dispatches: dispatches,
            epoch_nanos: t0.elapsed().as_nanos() as u64,
        })
    }

    /// Train for [`TrainConfig::epochs`] epochs with a fresh trainer,
    /// returning per-epoch loss/accuracy/dispatch stats. Deterministic
    /// for a fixed seed: bit-identical at every thread count and shard
    /// layout.
    pub fn fit(&mut self, config: &TrainConfig) -> Result<FitReport> {
        let mut tr = self.trainer(config.clone())?;
        let mut report = FitReport::default();
        for _ in 0..config.epochs {
            report.epochs.push(self.train_epoch(&mut tr)?);
        }
        Ok(report)
    }

    /// The dynamic spec in effect, if streaming updates are enabled.
    pub fn dynamic_spec(&self) -> Option<DynamicSpec> {
        self.dynamic.as_ref().map(|s| s.spec)
    }

    /// The epoch this session currently serves: 0 at build, +1 per
    /// [`Session::flip_epoch`]. Always 0 on a non-dynamic session.
    pub fn epoch(&self) -> u64 {
        self.dynamic.as_ref().map(|s| s.epoch).unwrap_or(0)
    }

    /// Describe the snapshot every run and served batch currently
    /// executes against (epoch, node/edge counts, pending updates).
    /// Buffered updates are invisible here until a flip — the
    /// isolation property `tests/integration_dynamic.rs` pins.
    pub fn snapshot(&self) -> GraphSnapshot {
        let (epoch, pending) = self
            .dynamic
            .as_ref()
            .map(|s| (s.epoch, s.log.len()))
            .unwrap_or((0, 0));
        GraphSnapshot::of(&self.hg, epoch, pending)
    }

    /// Buffer a batch of graph/parameter updates in the session's
    /// [`UpdateLog`] without touching the served snapshot; returns the
    /// pending count. Ids may reference nodes appended by updates
    /// buffered earlier (validation happens at the barrier, against the
    /// batch-simulated counts). Errors when the session was built
    /// without [`SessionBuilder::dynamic`] or the log is full — the
    /// bound backpressures the updater, never the serving path.
    pub fn apply_updates(&mut self, updates: Vec<GraphUpdate>) -> Result<usize> {
        let state = self.dynamic.as_mut().ok_or_else(|| {
            Error::config("Session built without .dynamic(..): no update log to append to")
        })?;
        state.log.append(updates)
    }

    /// The epoch barrier: atomically apply every pending update and
    /// advance the epoch. The pending log is validated as one batch
    /// (a bad update rejects the whole batch *before* any mutation —
    /// the rejected batch is discarded, serving continues on the old
    /// snapshot), then:
    ///
    /// 1. the graph is mutated and only the **affected** sub-CSRs are
    ///    re-derived, yielding the exact touched destination sets
    ///    ([`dynamic::apply_to_graph`]);
    /// 2. only the partition shards owning touched destinations (plus
    ///    the shards receiving appended nodes) rematerialize
    ///    ([`crate::partition::Partition::patch`]);
    /// 3. only the touched `(subgraph, dst)` aggregate keys and
    ///    rewritten `(type, node)` projection keys are evicted from
    ///    every reuse lane — untouched entries survive with their
    ///    generation intact;
    /// 4. a pending `SetWeights` is applied **last** (after graph
    ///    growth, so embedding shapes line up) through the same checks
    ///    as [`Session::set_weights`], degrading the flip to a full
    ///    invalidation. If the replacement is rejected, the structural
    ///    updates remain applied and serving continues on the old
    ///    weights with the caches conservatively cleared — re-flip with
    ///    a corrected set;
    /// 5. when a previous full run materialized the per-subgraph NA
    ///    bank, NA is recomputed **only for the touched rows** over
    ///    compact patch sub-CSRs and spliced in bit-identically
    ///    ([`exec::execute_patch`]), refreshing the cached full-graph
    ///    output; otherwise the cached output is dropped.
    ///
    /// Post-flip outputs are bit-identical to a cold session built from
    /// the fully-applied graph, across models × shards × reuse.
    pub fn flip_epoch(&mut self) -> Result<EpochReport> {
        let threads = self.threads;
        Self::with_pool(threads, || self.flip_epoch_unscoped())
    }

    fn flip_epoch_unscoped(&mut self) -> Result<EpochReport> {
        let t0 = Instant::now();
        if self.dynamic.is_none() {
            return Err(Error::config(
                "Session built without .dynamic(..): no epoch to flip",
            ));
        }
        if self.backend.caps().whole_model {
            return Err(Error::config(
                "flip_epoch: whole-model backends execute a static-shape artifact; \
                 dynamic sessions need a staged backend",
            ));
        }
        let updates = self.dynamic.as_mut().expect("checked above").log.drain();
        let updates_applied = updates.len();
        let mut patch = dynamic::apply_to_graph(&mut self.hg, &mut self.plan, updates)?;

        let shards_patched = match self.partition.as_mut() {
            Some(part) => part.patch(&self.plan, &patch)?,
            None => 0,
        };

        // targeted reuse eviction: touched aggregate rows everywhere;
        // rewritten projection rows only where FP actually reads raw
        // features (R-GCN projects learned embeddings instead)
        let mut evicted_proj = 0u64;
        let mut evicted_agg = 0u64;
        if let Some(lanes) = self.reuse.as_mut() {
            let feats_matter = self.plan.model != crate::models::ModelId::Rgcn;
            for lane in lanes.iter_mut() {
                for (si, touched) in patch.touched.iter().enumerate() {
                    for &d in touched {
                        if lane.evict_agg(si, d) {
                            evicted_agg += 1;
                        }
                    }
                }
                if feats_matter {
                    for &(ty, v) in &patch.feat_touched {
                        if lane.evict_proj(ty, v) {
                            evicted_proj += 1;
                        }
                    }
                }
            }
        }

        // weights last: graph growth already extended the embedding
        // tables, so a shape-compatible replacement lines up
        let full_invalidation = match patch.new_weights.take() {
            Some(w) => match self.set_weights(*w) {
                Ok(()) => true,
                Err(e) => {
                    // structural updates stay applied; drop everything
                    // derived so stale rows can't leak, then surface the
                    // rejection (epoch not advanced — re-flip to retry)
                    self.cached_output = None;
                    if let Some(state) = self.dynamic.as_mut() {
                        state.na_cache = None;
                    }
                    return Err(e);
                }
            },
            None => false,
        };

        // incremental NA recompute over the materialized bank
        // (field-disjoint borrows: the dynamic state alongside the
        // backend, plan, graph and scratch)
        let Session { dynamic, backend, gpu, plan, hg, scratch, cached_output, .. } =
            self;
        let state = dynamic.as_mut().expect("checked above");
        let (profile, na_rows) = match state.na_cache.as_mut() {
            Some(na_cache) if patch.touched_rows() > 0 => {
                let run = exec::execute_patch(
                    backend.as_ref(),
                    gpu,
                    plan,
                    hg,
                    &patch.touched,
                    na_cache,
                    scratch,
                )?;
                *cached_output = Some(run.output);
                (Some(run.profile), run.na_rows)
            }
            // nothing touched: the bank and cached output stay valid
            Some(_) => (None, 0),
            None => {
                *cached_output = None;
                (None, 0)
            }
        };
        state.epoch += 1;

        Ok(EpochReport {
            epoch: state.epoch,
            updates_applied,
            rebuilt_subgraphs: patch.rebuilt.iter().filter(|&&b| b).count(),
            patched_subgraphs: patch.touched.iter().filter(|t| !t.is_empty()).count(),
            na_rows_recomputed: na_rows,
            evicted_proj,
            evicted_agg,
            shards_patched,
            full_invalidation,
            pause_nanos: t0.elapsed().as_nanos() as u64,
            profile,
        })
    }
}

/// One shard-affine sub-batch of the partitioned serving path: sample
/// the group's neighborhood (through the shard's reuse-cache lane when
/// one is given) and execute it against the shard's persistent kernel
/// context (so its scratch arena recycles stage outputs across
/// dispatches, like the unsharded path), returning seed →
/// embedding-row pairs. A free function (not a closure) so the pooled
/// and inline call sites can pass differently-lived backends.
#[allow(clippy::too_many_arguments)]
fn shard_batch_task(
    backend: &dyn ExecBackend,
    hg: &HeteroGraph,
    plan: &ModelPlan,
    gpu: &GpuModel,
    policy: SchedulePolicy,
    sampler: &NeighborSampler,
    group: &[u32],
    cache: Option<&mut ReuseCache>,
    scratch: &mut Ctx,
) -> Result<Vec<(u32, Vec<f32>)>> {
    let (sampled, run) = match cache {
        Some(cache) => {
            let sampled = sampler.sample_with_cache(hg, plan, group, cache)?;
            let run = exec::execute_reuse(backend, gpu, &sampled, policy, scratch, cache)?;
            (sampled, run)
        }
        None => {
            let sampled = sampler.sample(hg, plan, group)?;
            let run = exec::execute(
                backend,
                gpu,
                &sampled.plan,
                &sampled.graph,
                policy,
                scratch,
            )?;
            (sampled, run)
        }
    };
    let rows = sampled
        .seeds
        .iter()
        .zip(&sampled.seed_rows)
        .map(|(&g, &r)| (g, run.output.row(r as usize).to_vec()))
        .collect();
    // park the finished stage outputs for the next dispatch of this shard
    scratch.arena.give(run.output.into_vec());
    for t in run.na_results {
        scratch.arena.give(t.into_vec());
    }
    Ok(rows)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::profiler::StageId;

    fn ci_builder() -> SessionBuilder {
        Session::builder()
            .dataset(DatasetId::Imdb)
            .scale(DatasetScale::ci())
            .model(ModelId::Han)
    }

    #[test]
    fn builder_defaults_and_accessors() {
        let session = ci_builder().build().unwrap();
        assert_eq!(session.backend_name(), "native");
        assert_eq!(session.policy(), SchedulePolicy::Sequential);
        assert_eq!(session.profiling(), Profiling::Counters);
        assert_eq!(session.runs(), 0);
        assert_eq!(session.plan().model, ModelId::Han);
    }

    #[test]
    fn builder_requires_graph_source() {
        assert!(Session::builder().build().is_err());
    }

    #[test]
    fn run_produces_profile_and_output() {
        let mut session = ci_builder().build().unwrap();
        let run = session.run().unwrap();
        assert!(run.output.frob_norm() > 0.0);
        assert_eq!(run.na_results.len(), 2);
        assert!(!run.profile.kernels.is_empty());
        let pct = run.profile.stage_percentages();
        assert!((pct.values().sum::<f64>() - 100.0).abs() < 1e-6);
        assert_eq!(session.runs(), 1);
    }

    #[test]
    fn profiling_traces_reach_the_kernels() {
        let mut traced = ci_builder().profiling(Profiling::Traces).build().unwrap();
        let run = traced.run().unwrap();
        assert!(
            run.profile.kernels.iter().any(|k| k.exec.trace.is_some()),
            "Profiling::Traces must record gather traces"
        );
        let mut plain = ci_builder().build().unwrap();
        let run = plain.run().unwrap();
        assert!(run.profile.kernels.iter().all(|k| k.exec.trace.is_none()));
    }

    #[test]
    fn policies_agree_through_session() {
        let mut seq = ci_builder().build().unwrap();
        let baseline = seq.run().unwrap();
        for policy in [
            SchedulePolicy::InterSubgraphParallel { workers: 2 },
            SchedulePolicy::FusedSubgraph { workers: 2 },
            SchedulePolicy::BoundAwareMixing { workers: 2 },
        ] {
            let mut s = ci_builder().schedule(policy).build().unwrap();
            let run = s.run().unwrap();
            assert!(
                run.output.allclose(&baseline.output, 1e-4, 1e-5),
                "{} diverges from sequential",
                policy.label()
            );
        }
    }

    #[test]
    fn set_schedule_swaps_between_runs() {
        let mut session = ci_builder().build().unwrap();
        let seq = session.run().unwrap();
        session.set_schedule(SchedulePolicy::InterSubgraphParallel { workers: 2 });
        let par = session.run().unwrap();
        assert!(par.output.allclose(&seq.output, 1e-4, 1e-5));
        assert!(par.report.modeled_makespan_ns <= seq.report.modeled_makespan_ns + 1.0);
        assert_eq!(session.runs(), 2);
    }

    #[test]
    fn fused_policy_attributes_fp_to_na() {
        let mut session = ci_builder()
            .schedule(SchedulePolicy::FusedSubgraph { workers: 2 })
            .build()
            .unwrap();
        let run = session.run().unwrap();
        let fp = run
            .profile
            .kernels
            .iter()
            .filter(|k| k.stage == StageId::FeatureProjection)
            .count();
        assert_eq!(fp, 0);
        assert!(run.profile.kernels.iter().any(|k| k.exec.name == "sgemm"));
    }

    #[test]
    fn run_batch_reuses_cached_output() {
        let mut session = ci_builder().build().unwrap();
        let rows = session.run_batch(&[0, 1, 2]).unwrap();
        assert_eq!(rows.len(), 3);
        assert_eq!(session.runs(), 1);
        // second batch: no new run
        let again = session.run_batch(&[5_000_000]).unwrap();
        assert_eq!(session.runs(), 1);
        assert_eq!(again.len(), 1);
        // invalidation forces a recompute
        session.invalidate();
        let _ = session.run_batch(&[0]).unwrap();
        assert_eq!(session.runs(), 2);
    }

    #[test]
    fn run_batch_sampled_executes_per_call() {
        let mut session = ci_builder()
            .sampling(crate::sampler::SamplingSpec::uniform(8, 1))
            .build()
            .unwrap();
        assert!(session.sampling().is_some());
        let rows = session.run_batch(&[0, 1, 0]).unwrap();
        assert_eq!(rows.len(), 3);
        assert_eq!(rows[0].len(), session.plan().config.hidden_dim);
        assert_eq!(rows[0], rows[2], "duplicate ids share a seed row");
        assert_eq!(session.runs(), 1);
        // sampled serving never reuses a stale cache: every batch executes
        let _ = session.run_batch(&[2]).unwrap();
        assert_eq!(session.runs(), 2);
    }

    #[test]
    fn sample_batch_requires_spec_and_wraps_ids() {
        let session = ci_builder().build().unwrap();
        assert!(session.sample_batch(&[0]).is_err());
        let session = ci_builder()
            .sampling(crate::sampler::SamplingSpec::uniform(4, 1))
            .build()
            .unwrap();
        let n = session.graph().node_type(session.plan().target).count as u32;
        let s = session.sample_batch(&[n + 3, 3]).unwrap();
        // both ids wrap onto seed 3
        assert_eq!(s.seeds, vec![3]);
    }

    #[test]
    fn reuse_requires_sampling() {
        assert!(ci_builder().reuse(ReuseSpec::rows(64)).build().is_err());
        assert!(ci_builder()
            .sampling(crate::sampler::SamplingSpec::uniform(8, 1))
            .reuse(ReuseSpec::rows(64))
            .build()
            .is_ok());
    }

    #[test]
    fn reuse_batches_accumulate_hits_and_stay_bit_identical() {
        let mut s = ci_builder()
            .sampling(crate::sampler::SamplingSpec::uniform(usize::MAX, 1))
            .reuse(ReuseSpec::rows(1 << 12))
            .build()
            .unwrap();
        assert!(s.reuse_spec().is_some());
        let a = s.run_batch(&[0, 1, 2]).unwrap();
        assert_eq!(s.reuse_stats().unwrap().proj_hits, 0, "cold cache cannot hit");
        let b = s.run_batch(&[0, 1, 2]).unwrap();
        assert_eq!(a, b, "repeated identical batches must be bit-identical");
        let st = s.reuse_stats().unwrap();
        assert!(st.proj_hits > 0 && st.agg_hits > 0, "warm batch must hit: {st:?}");
        // invalidation clears the caches; recomputation reproduces rows
        s.invalidate();
        assert_eq!(s.reuse_stats().unwrap().invalidations, 1);
        let c = s.run_batch(&[0, 1, 2]).unwrap();
        assert_eq!(a, c);
    }

    #[test]
    fn aggregate_only_spec_skips_projection_lookups() {
        let mut s = ci_builder()
            .sampling(crate::sampler::SamplingSpec::uniform(usize::MAX, 1))
            .reuse(ReuseSpec::caps(0, 1 << 12))
            .build()
            .unwrap();
        let _ = s.run_batch(&[0, 1, 2]).unwrap();
        let _ = s.run_batch(&[0, 1, 2]).unwrap();
        let st = s.reuse_stats().unwrap();
        assert_eq!(
            st.proj_hits + st.proj_misses,
            0,
            "a disabled projection cache must never be consulted: {st:?}"
        );
        assert!(st.agg_hits > 0, "aggregate reuse must still apply: {st:?}");
    }

    #[test]
    fn pjrt_spec_without_artifacts_fails_cleanly() {
        let err = ci_builder().pjrt("/nonexistent-artifacts").build();
        // Either the PJRT client is unavailable (no `pjrt` feature) or
        // the directory has no manifest — both must surface as errors,
        // never panics. With a real client the build itself succeeds and
        // the first run fails on the missing manifest.
        if let Ok(mut session) = err {
            assert!(session.run().is_err());
        }
    }

    #[test]
    fn dynamic_surface_requires_the_builder_knob() {
        let mut s = ci_builder().build().unwrap();
        assert_eq!(s.epoch(), 0);
        assert!(s.dynamic_spec().is_none());
        assert!(s.apply_updates(Vec::new()).is_err());
        assert!(s.flip_epoch().is_err());
        let snap = s.snapshot();
        assert_eq!(snap.epoch, 0);
        assert_eq!(snap.pending_updates, 0);
    }

    #[test]
    fn flip_epoch_patches_in_place_bit_identically() {
        use crate::dynamic::{DynamicSpec, GraphUpdate};
        let mut s = ci_builder().dynamic(DynamicSpec::default()).build().unwrap();
        assert_eq!(s.dynamic_spec(), Some(DynamicSpec::default()));
        let _ = s.run().unwrap();

        // a genuinely new M-D edge from a director who already directs
        // (so it propagates into the composed MDM adjacency)
        let (md, dst, src) = {
            let hg = s.graph();
            let md = hg.relations().iter().position(|r| r.name == "M-D").unwrap();
            let dm = hg.relations().iter().position(|r| r.name == "D-M").unwrap();
            let d = (0..hg.relation(dm).adj.n_rows)
                .filter_map(|r| hg.relation(dm).adj.row(r).first().copied())
                .next()
                .unwrap();
            let row = hg.relation(md).adj.row(d as usize);
            let c = (0..hg.relation(md).adj.n_cols as u32)
                .find(|c| row.binary_search(c).is_err())
                .unwrap();
            (md, d, c)
        };
        let before = s.snapshot();
        s.apply_updates(vec![GraphUpdate::AddEdge { relation: md, dst, src }]).unwrap();
        // snapshot isolation: the buffered edge is invisible until the flip
        let pending = s.snapshot();
        assert_eq!(pending.edge_counts, before.edge_counts);
        assert_eq!(pending.pending_updates, 1);

        let report = s.flip_epoch().unwrap();
        assert_eq!(report.epoch, 1);
        assert_eq!(report.updates_applied, 1);
        assert!(report.rebuilt_subgraphs >= 1);
        assert!(report.na_rows_recomputed > 0);
        assert!(report.profile.is_some(), "patch recompute carries a profile");
        assert_eq!(s.epoch(), 1);
        assert_eq!(s.snapshot().pending_updates, 0);

        // the flip refreshed the cached full-graph output in place —
        // batches read it without a new run, and the rows are
        // bit-identical to a cold session over the fully-applied graph
        let rows = s.run_batch(&[0, 1, 2]).unwrap();
        assert_eq!(s.runs(), 1);
        let mut cold = Session::builder()
            .graph(s.graph().clone())
            .model(ModelId::Han)
            .build()
            .unwrap();
        assert_eq!(rows, cold.run_batch(&[0, 1, 2]).unwrap());
    }

    #[test]
    fn empty_flip_advances_the_epoch_and_keeps_the_cache() {
        use crate::dynamic::DynamicSpec;
        let mut s = ci_builder().dynamic(DynamicSpec::default()).build().unwrap();
        let _ = s.run_batch(&[0]).unwrap();
        assert_eq!(s.runs(), 1);
        let report = s.flip_epoch().unwrap();
        assert_eq!(report.epoch, 1);
        assert_eq!(report.updates_applied, 0);
        assert_eq!(report.na_rows_recomputed, 0);
        // nothing touched: the cached output survives the barrier
        let _ = s.run_batch(&[0]).unwrap();
        assert_eq!(s.runs(), 1);
    }

    #[test]
    fn cluster_builder_defaults_partition_and_matches_monolith() {
        let mut mono = ci_builder().build().unwrap();
        let base = mono.run().unwrap();
        let mut dist = ci_builder().cluster(ClusterSpec::new(2)).build().unwrap();
        assert_eq!(dist.partition().map(|p| p.num_shards()), Some(2));
        let run = dist.run().unwrap();
        assert_eq!(
            run.output.as_slice(),
            base.output.as_slice(),
            "distributed forward must be bit-identical to the monolith"
        );
        let stats = dist.cluster_stats().unwrap();
        assert_eq!(stats.waves, 1);
        assert_eq!(stats.retired_workers, 0);
        assert!(dist.cluster().unwrap().transport_stats().bytes > 0, "rows crossed the wire");
    }

    #[test]
    fn handle_worker_down_requires_cluster_and_replaces() {
        let mut s = ci_builder().build().unwrap();
        assert!(s.handle_worker_down(0).is_err());
        let mut dist = ci_builder().cluster(ClusterSpec::new(2)).build().unwrap();
        let moved = dist.handle_worker_down(1).unwrap();
        assert_eq!(moved, 1, "worker 1 owned exactly one of the two shards");
        assert_eq!(dist.cluster().unwrap().placement(), &[0, 0]);
        // the surviving worker serves the whole forward
        let run = dist.run().unwrap();
        assert!(run.output.frob_norm() > 0.0);
        assert_eq!(dist.cluster_stats().unwrap().retired_workers, 1);
    }

    #[test]
    fn policy_labels_and_all() {
        assert_eq!(SchedulePolicy::Sequential.label(), "sequential");
        assert!(SchedulePolicy::FusedSubgraph { workers: 3 }.label().contains('3'));
        assert_eq!(SchedulePolicy::all(2).len(), 4);
    }
}
