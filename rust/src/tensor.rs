//! Dense 2-D `f32` tensors.
//!
//! Everything the paper's workloads move through kernels is a dense
//! matrix of node features (`[num_nodes, feat_dim]`), a projection matrix
//! (`[in_dim, out_dim]`), or a stack of per-metapath results
//! (`[num_metapaths * num_nodes, feat_dim]` after `Concat`). A small
//! owned row-major matrix type is all the substrate needs; keeping it
//! minimal makes FLOP/byte accounting in [`crate::kernels`] exact.

use crate::{Error, Result};

/// Row-major owned `f32` matrix.
#[derive(Debug, Clone, PartialEq)]
pub struct Tensor {
    rows: usize,
    cols: usize,
    data: Vec<f32>,
}

impl Tensor {
    /// Zero-filled tensor of the given shape.
    pub fn zeros(rows: usize, cols: usize) -> Tensor {
        Tensor { rows, cols, data: vec![0.0; rows * cols] }
    }

    /// Tensor filled with a constant.
    pub fn full(rows: usize, cols: usize, value: f32) -> Tensor {
        Tensor { rows, cols, data: vec![value; rows * cols] }
    }

    /// Build from an existing row-major buffer.
    pub fn from_vec(rows: usize, cols: usize, data: Vec<f32>) -> Result<Tensor> {
        if data.len() != rows * cols {
            return Err(Error::shape(format!(
                "buffer len {} != {}x{}",
                data.len(),
                rows,
                cols
            )));
        }
        Ok(Tensor { rows, cols, data })
    }

    /// Random-normal tensor (Glorot-ish scale `s`), deterministic in `rng`.
    pub fn randn(rows: usize, cols: usize, scale: f32, rng: &mut crate::util::Pcg32) -> Tensor {
        let data = (0..rows * cols).map(|_| rng.gen_normal() * scale).collect();
        Tensor { rows, cols, data }
    }

    /// Identity-like one-hot features: row i has a 1.0 at column `i % cols`.
    /// This mirrors how DBLP assigns one-hot features to paper nodes.
    pub fn one_hot(rows: usize, cols: usize) -> Tensor {
        let mut t = Tensor::zeros(rows, cols);
        for i in 0..rows {
            let c = i % cols;
            t.data[i * cols + c] = 1.0;
        }
        t
    }

    /// Number of rows.
    #[inline]
    pub fn rows(&self) -> usize {
        self.rows
    }

    /// Number of columns.
    #[inline]
    pub fn cols(&self) -> usize {
        self.cols
    }

    /// `(rows, cols)` pair.
    #[inline]
    pub fn shape(&self) -> (usize, usize) {
        (self.rows, self.cols)
    }

    /// Total element count.
    #[inline]
    pub fn len(&self) -> usize {
        self.data.len()
    }

    /// True when the tensor holds no elements.
    #[inline]
    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    /// Size in bytes (f32).
    #[inline]
    pub fn bytes(&self) -> usize {
        self.data.len() * 4
    }

    /// Immutable raw buffer.
    #[inline]
    pub fn as_slice(&self) -> &[f32] {
        &self.data
    }

    /// Consume the tensor, returning its backing buffer (how finished
    /// stage outputs flow back into a [`crate::kernels::ScratchArena`]).
    #[inline]
    pub fn into_vec(self) -> Vec<f32> {
        self.data
    }

    /// Mutable raw buffer.
    #[inline]
    pub fn as_mut_slice(&mut self) -> &mut [f32] {
        &mut self.data
    }

    /// Immutable view of row `r`.
    #[inline]
    pub fn row(&self, r: usize) -> &[f32] {
        debug_assert!(r < self.rows);
        &self.data[r * self.cols..(r + 1) * self.cols]
    }

    /// Mutable view of row `r`.
    #[inline]
    pub fn row_mut(&mut self, r: usize) -> &mut [f32] {
        debug_assert!(r < self.rows);
        &mut self.data[r * self.cols..(r + 1) * self.cols]
    }

    /// Element access.
    #[inline]
    pub fn get(&self, r: usize, c: usize) -> f32 {
        debug_assert!(r < self.rows && c < self.cols);
        self.data[r * self.cols + c]
    }

    /// Element write.
    #[inline]
    pub fn set(&mut self, r: usize, c: usize, v: f32) {
        debug_assert!(r < self.rows && c < self.cols);
        self.data[r * self.cols + c] = v;
    }

    /// Copy `src` into row `r`.
    pub fn set_row(&mut self, r: usize, src: &[f32]) {
        debug_assert_eq!(src.len(), self.cols);
        self.row_mut(r).copy_from_slice(src);
    }

    /// Transposed copy.
    pub fn transposed(&self) -> Tensor {
        let mut out = Tensor::zeros(self.cols, self.rows);
        for r in 0..self.rows {
            for c in 0..self.cols {
                out.data[c * self.rows + r] = self.data[r * self.cols + c];
            }
        }
        out
    }

    /// Take a contiguous row range `[start, end)` as a new tensor.
    pub fn slice_rows(&self, start: usize, end: usize) -> Result<Tensor> {
        if start > end || end > self.rows {
            return Err(Error::shape(format!(
                "row slice {start}..{end} out of 0..{}",
                self.rows
            )));
        }
        Ok(Tensor {
            rows: end - start,
            cols: self.cols,
            data: self.data[start * self.cols..end * self.cols].to_vec(),
        })
    }

    /// Frobenius norm.
    pub fn frob_norm(&self) -> f64 {
        self.data.iter().map(|&x| (x as f64) * (x as f64)).sum::<f64>().sqrt()
    }

    /// Max absolute element difference against another tensor.
    pub fn max_abs_diff(&self, other: &Tensor) -> Result<f32> {
        if self.shape() != other.shape() {
            return Err(Error::shape(format!(
                "shapes {:?} vs {:?}",
                self.shape(),
                other.shape()
            )));
        }
        Ok(self
            .data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0f32, f32::max))
    }

    /// Approximate equality with combined absolute/relative tolerance:
    /// `|a-b| <= atol + rtol * |b|` elementwise.
    pub fn allclose(&self, other: &Tensor, rtol: f32, atol: f32) -> bool {
        self.shape() == other.shape()
            && self
                .data
                .iter()
                .zip(&other.data)
                .all(|(a, b)| (a - b).abs() <= atol + rtol * b.abs())
    }
}

/// Stack tensors vertically (all must share `cols`).
pub fn vstack(parts: &[&Tensor]) -> Result<Tensor> {
    if parts.is_empty() {
        return Err(Error::shape("vstack of zero tensors"));
    }
    let cols = parts[0].cols();
    let mut rows = 0;
    for p in parts {
        if p.cols() != cols {
            return Err(Error::shape(format!("vstack cols {} vs {}", p.cols(), cols)));
        }
        rows += p.rows();
    }
    let mut data = Vec::with_capacity(rows * cols);
    for p in parts {
        data.extend_from_slice(p.as_slice());
    }
    Tensor::from_vec(rows, cols, data)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Pcg32;

    #[test]
    fn construction_and_access() {
        let mut t = Tensor::zeros(2, 3);
        assert_eq!(t.shape(), (2, 3));
        t.set(1, 2, 5.0);
        assert_eq!(t.get(1, 2), 5.0);
        assert_eq!(t.row(1), &[0.0, 0.0, 5.0]);
        assert_eq!(t.bytes(), 24);
    }

    #[test]
    fn from_vec_validates() {
        assert!(Tensor::from_vec(2, 2, vec![0.0; 3]).is_err());
        assert!(Tensor::from_vec(2, 2, vec![0.0; 4]).is_ok());
    }

    #[test]
    fn transpose_roundtrip() {
        let mut rng = Pcg32::seeded(11);
        let t = Tensor::randn(4, 7, 1.0, &mut rng);
        let tt = t.transposed().transposed();
        assert!(t.allclose(&tt, 0.0, 0.0));
    }

    #[test]
    fn one_hot_rows_sum_to_one() {
        let t = Tensor::one_hot(10, 4);
        for r in 0..10 {
            let s: f32 = t.row(r).iter().sum();
            assert_eq!(s, 1.0);
        }
    }

    #[test]
    fn slice_rows_bounds() {
        let t = Tensor::full(5, 2, 1.0);
        let s = t.slice_rows(1, 4).unwrap();
        assert_eq!(s.shape(), (3, 2));
        assert!(t.slice_rows(4, 6).is_err());
    }

    #[test]
    fn vstack_shapes() {
        let a = Tensor::full(2, 3, 1.0);
        let b = Tensor::full(1, 3, 2.0);
        let v = vstack(&[&a, &b]).unwrap();
        assert_eq!(v.shape(), (3, 3));
        assert_eq!(v.get(2, 0), 2.0);
        let c = Tensor::full(1, 4, 0.0);
        assert!(vstack(&[&a, &c]).is_err());
    }

    #[test]
    fn allclose_tolerances() {
        let a = Tensor::full(1, 1, 1.0);
        let b = Tensor::full(1, 1, 1.0 + 1e-6);
        assert!(a.allclose(&b, 1e-5, 0.0));
        assert!(!a.allclose(&b, 1e-8, 0.0));
    }
}
