//! Mini property-testing framework (proptest is not in the offline
//! vendor set) plus shared generators for graphs and tensors.
//!
//! `check(...)` runs a property over `n` generated cases; on failure it
//! greedily shrinks the case via the strategy's `shrink` and reports the
//! smallest failing input. Deterministic: seeded PCG, so failures
//! reproduce.

use crate::cluster::{Message, RowBlock};
use crate::graph::sparse::{Coo, Csr};
use crate::serving::clock::{Clock, Nanos};
use crate::tensor::Tensor;
use crate::util::Pcg32;

use std::sync::{Arc, Condvar, Mutex, MutexGuard};
use std::time::Duration;

/// A deterministic clock for driving the serving runtime in tests:
/// time only moves when the test calls [`VirtualClock::advance`], so
/// size-vs-timeout batch closing, deadline expiry and token-bucket
/// refill are exercised without real sleeps.
///
/// Timed waits park on a short *real* safety timeout (so a wait issued
/// just before an `advance` notification still re-checks its predicate
/// promptly rather than hanging), but the predicates the serving loop
/// re-checks after every wake depend only on virtual time — outcomes
/// are deterministic even though wake timing is not.
#[derive(Debug, Default)]
pub struct VirtualClock {
    now: Mutex<Nanos>,
    wakers: Mutex<Vec<Arc<Condvar>>>,
}

impl VirtualClock {
    /// A clock frozen at t = 0.
    pub fn new() -> VirtualClock {
        VirtualClock::default()
    }

    /// Advance virtual time and wake every registered waiter.
    pub fn advance(&self, by: Duration) {
        {
            let mut now = self.now.lock().unwrap_or_else(|e| e.into_inner());
            *now += by.as_nanos() as Nanos;
        }
        for cv in self.wakers.lock().unwrap_or_else(|e| e.into_inner()).iter() {
            cv.notify_all();
        }
    }
}

impl Clock for VirtualClock {
    fn now(&self) -> Nanos {
        *self.now.lock().unwrap_or_else(|e| e.into_inner())
    }

    fn register_waker(&self, cv: &Arc<Condvar>) {
        self.wakers.lock().unwrap_or_else(|e| e.into_inner()).push(Arc::clone(cv));
    }

    fn wait_deadline<'a, T>(
        &self,
        cv: &Condvar,
        guard: MutexGuard<'a, T>,
        deadline: Nanos,
    ) -> MutexGuard<'a, T> {
        if self.now() >= deadline {
            return guard;
        }
        // short real-time nap as a safety net against missed wakeups;
        // `advance` notifies registered wakers to cut it short
        cv.wait_timeout(guard, Duration::from_millis(20))
            .unwrap_or_else(|e| e.into_inner())
            .0
    }
}

/// A generation strategy: produce a case from randomness, shrink a case
/// toward smaller ones.
pub trait Strategy {
    /// The generated value type.
    type Value: std::fmt::Debug + Clone;
    /// Generate one case.
    fn generate(&self, rng: &mut Pcg32) -> Self::Value;
    /// Candidate shrinks of a failing case (smaller-first).
    fn shrink(&self, value: &Self::Value) -> Vec<Self::Value> {
        let _ = value;
        Vec::new()
    }
}

/// Run `prop` over `cases` generated inputs; panics with the smallest
/// failing case found.
pub fn check<S: Strategy>(
    name: &str,
    seed: u64,
    cases: usize,
    strategy: &S,
    prop: impl Fn(&S::Value) -> bool,
) {
    let mut rng = Pcg32::new(seed, 0x7e57);
    for case_idx in 0..cases {
        let value = strategy.generate(&mut rng);
        if !prop(&value) {
            // shrink greedily
            let mut smallest = value.clone();
            let mut improved = true;
            let mut rounds = 0;
            while improved && rounds < 1000 {
                improved = false;
                rounds += 1;
                for cand in strategy.shrink(&smallest) {
                    if !prop(&cand) {
                        smallest = cand;
                        improved = true;
                        break;
                    }
                }
            }
            panic!(
                "property '{name}' failed at case {case_idx} (seed {seed});\n\
                 smallest failing input after shrinking:\n{smallest:#?}"
            );
        }
    }
}

/// Strategy: CSR matrices up to the given dimensions/density.
#[derive(Debug, Clone)]
pub struct CsrStrategy {
    /// Max rows.
    pub max_rows: usize,
    /// Max cols.
    pub max_cols: usize,
    /// Max density (0..1].
    pub max_density: f64,
}

impl Default for CsrStrategy {
    fn default() -> Self {
        CsrStrategy { max_rows: 40, max_cols: 40, max_density: 0.3 }
    }
}

impl Strategy for CsrStrategy {
    type Value = Csr;

    fn generate(&self, rng: &mut Pcg32) -> Csr {
        let n_rows = 1 + rng.gen_range(self.max_rows);
        let n_cols = 1 + rng.gen_range(self.max_cols);
        let density = rng.gen_f64() * self.max_density;
        let target = ((n_rows * n_cols) as f64 * density) as usize;
        let mut edges = Vec::with_capacity(target);
        for _ in 0..target {
            edges.push((rng.gen_range(n_rows) as u32, rng.gen_range(n_cols) as u32));
        }
        Coo::from_edges(n_rows, n_cols, edges).expect("in-bounds").to_csr()
    }

    fn shrink(&self, value: &Csr) -> Vec<Csr> {
        let mut out = Vec::new();
        // drop the last row
        if value.n_rows > 1 {
            let n = value.n_rows - 1;
            out.push(Csr {
                n_rows: n,
                n_cols: value.n_cols,
                indptr: value.indptr[..=n].to_vec(),
                indices: value.indices[..value.indptr[n] as usize].to_vec(),
            });
        }
        // halve the nonzeros (kept per-row prefix)
        if value.nnz() > 0 {
            let mut indptr = vec![0u32; value.n_rows + 1];
            let mut indices = Vec::new();
            for r in 0..value.n_rows {
                let row = value.row(r);
                let keep = row.len() / 2;
                indices.extend_from_slice(&row[..keep]);
                indptr[r + 1] = indices.len() as u32;
            }
            out.push(Csr {
                n_rows: value.n_rows,
                n_cols: value.n_cols,
                indptr,
                indices,
            });
        }
        out
    }
}

/// Strategy: dense tensors up to the given dims, values in [-scale, scale].
#[derive(Debug, Clone)]
pub struct TensorStrategy {
    /// Max rows.
    pub max_rows: usize,
    /// Max cols.
    pub max_cols: usize,
    /// Value scale.
    pub scale: f32,
}

impl Default for TensorStrategy {
    fn default() -> Self {
        TensorStrategy { max_rows: 24, max_cols: 24, scale: 2.0 }
    }
}

impl Strategy for TensorStrategy {
    type Value = Tensor;

    fn generate(&self, rng: &mut Pcg32) -> Tensor {
        let rows = 1 + rng.gen_range(self.max_rows);
        let cols = 1 + rng.gen_range(self.max_cols);
        let data = (0..rows * cols)
            .map(|_| (rng.gen_f32() * 2.0 - 1.0) * self.scale)
            .collect();
        Tensor::from_vec(rows, cols, data).expect("consistent dims")
    }

    fn shrink(&self, value: &Tensor) -> Vec<Tensor> {
        let mut out = Vec::new();
        if value.rows() > 1 {
            out.push(value.slice_rows(0, value.rows() - 1).expect("in-bounds"));
        }
        out
    }
}

/// Strategy: cluster wire [`Message`]s across **every** variant, with
/// arbitrary payload sizes — including empty row blocks (the shape of
/// an empty halo exchange) — and adversarial f32 payloads (NaN, ±∞,
/// −0.0, denormals), which the codec must round-trip bit-exactly.
#[derive(Debug, Clone)]
pub struct MessageStrategy {
    /// Max rows per generated block (0 rows is always possible).
    pub max_rows: usize,
    /// Max cols per generated block.
    pub max_cols: usize,
}

impl Default for MessageStrategy {
    fn default() -> Self {
        MessageStrategy { max_rows: 12, max_cols: 8 }
    }
}

impl MessageStrategy {
    fn block(&self, rng: &mut Pcg32) -> RowBlock {
        let rows = rng.gen_range(self.max_rows + 1);
        let cols = (1 + rng.gen_range(self.max_cols)) as u32;
        let data = (0..rows * cols as usize)
            .map(|i| match rng.gen_range(8) {
                0 => f32::NAN,
                1 => f32::INFINITY,
                2 => f32::NEG_INFINITY,
                3 => -0.0,
                4 => f32::MIN_POSITIVE / 2.0, // subnormal
                _ => (rng.gen_f32() * 2.0 - 1.0) * 1e3 + i as f32,
            })
            .collect();
        RowBlock {
            ids: (0..rows).map(|_| rng.gen_range(1 << 20) as u32).collect(),
            cols,
            data,
        }
    }
}

impl Strategy for MessageStrategy {
    type Value = Message;

    fn generate(&self, rng: &mut Pcg32) -> Message {
        let shard = rng.gen_range(64) as u32;
        let worker = rng.gen_range(16) as u32;
        let ty = rng.gen_range(8) as u32;
        match rng.gen_range(10) {
            0 => Message::Place { shard, worker },
            1 => Message::Heartbeat { worker },
            2 => Message::Drain { worker },
            3 => Message::Retire { worker },
            4 => Message::Epoch { epoch: rng.gen_range(1 << 30) as u64 },
            5 => Message::Weights {
                version: rng.gen_range(1 << 30) as u64,
                payload: (0..rng.gen_range(64)).map(|_| rng.gen_range(256) as u8).collect(),
            },
            6 => Message::Halo { shard, ty, block: self.block(rng) },
            7 => Message::FpRows { shard, ty, block: self.block(rng) },
            8 => Message::NaRows { shard, subgraph: ty, block: self.block(rng) },
            _ => Message::BatchRows { shard, block: self.block(rng) },
        }
    }

    fn shrink(&self, value: &Message) -> Vec<Message> {
        fn halve(b: &RowBlock) -> Option<RowBlock> {
            if b.ids.is_empty() {
                return None;
            }
            let keep = b.ids.len() / 2;
            Some(RowBlock {
                ids: b.ids[..keep].to_vec(),
                cols: b.cols,
                data: b.data[..keep * b.cols as usize].to_vec(),
            })
        }
        match value {
            Message::Halo { shard, ty, block } => halve(block)
                .map(|block| Message::Halo { shard: *shard, ty: *ty, block })
                .into_iter()
                .collect(),
            Message::FpRows { shard, ty, block } => halve(block)
                .map(|block| Message::FpRows { shard: *shard, ty: *ty, block })
                .into_iter()
                .collect(),
            Message::NaRows { shard, subgraph, block } => halve(block)
                .map(|block| Message::NaRows { shard: *shard, subgraph: *subgraph, block })
                .into_iter()
                .collect(),
            Message::BatchRows { shard, block } => halve(block)
                .map(|block| Message::BatchRows { shard: *shard, block })
                .into_iter()
                .collect(),
            Message::Weights { version, payload } if !payload.is_empty() => {
                vec![Message::Weights {
                    version: *version,
                    payload: payload[..payload.len() / 2].to_vec(),
                }]
            }
            _ => Vec::new(),
        }
    }
}

/// Pair strategy combinator.
pub struct Pair<A, B>(pub A, pub B);

impl<A: Strategy, B: Strategy> Strategy for Pair<A, B> {
    type Value = (A::Value, B::Value);

    fn generate(&self, rng: &mut Pcg32) -> Self::Value {
        (self.0.generate(rng), self.1.generate(rng))
    }

    fn shrink(&self, value: &Self::Value) -> Vec<Self::Value> {
        let mut out: Vec<Self::Value> = self
            .0
            .shrink(&value.0)
            .into_iter()
            .map(|a| (a, value.1.clone()))
            .collect();
        out.extend(self.1.shrink(&value.1).into_iter().map(|b| (value.0.clone(), b)));
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        check("csr valid", 1, 50, &CsrStrategy::default(), |csr| {
            csr.validate().is_ok()
        });
    }

    #[test]
    #[should_panic(expected = "property 'always false' failed")]
    fn failing_property_panics_with_shrink() {
        check("always false", 2, 5, &CsrStrategy::default(), |_| false);
    }

    #[test]
    fn shrinking_reaches_small_cases() {
        // property violated for any csr with > 4 rows; the shrinker
        // should find a small-ish counterexample (checked via panic text)
        let result = std::panic::catch_unwind(|| {
            check("rows<=4", 3, 50, &CsrStrategy::default(), |csr| csr.n_rows <= 4)
        });
        let msg = *result.unwrap_err().downcast::<String>().unwrap();
        assert!(msg.contains("n_rows: 5"), "shrunk to minimal: {msg}");
    }

    #[test]
    fn tensor_strategy_bounds() {
        let s = TensorStrategy::default();
        let mut rng = Pcg32::seeded(4);
        for _ in 0..20 {
            let t = s.generate(&mut rng);
            assert!(t.rows() >= 1 && t.rows() <= 24);
            assert!(t.as_slice().iter().all(|v| v.abs() <= 2.0));
        }
    }

    #[test]
    fn virtual_clock_advances_and_wakes() {
        let clock = Arc::new(VirtualClock::new());
        assert_eq!(clock.now(), 0);
        clock.advance(Duration::from_millis(3));
        assert_eq!(clock.now(), 3_000_000);
        // a waiter registered with the clock is woken by advance
        let cv = Arc::new(Condvar::new());
        clock.register_waker(&cv);
        let m = Mutex::new(());
        let g = m.lock().unwrap();
        // deadline already passed: returns immediately without waiting
        let g = clock.wait_deadline(&cv, g, 1_000_000);
        // deadline in the future: returns after the safety timeout even
        // with no notification (bounded, not hung)
        let _g = clock.wait_deadline(&cv, g, u64::MAX);
        assert_eq!(clock.now(), 3_000_000, "waiting does not move virtual time");
    }

    #[test]
    fn message_strategy_covers_every_variant() {
        let s = MessageStrategy::default();
        let mut rng = Pcg32::seeded(6);
        let mut tags = std::collections::BTreeSet::new();
        let mut saw_empty_block = false;
        for _ in 0..400 {
            let m = s.generate(&mut rng);
            tags.insert(m.tag());
            if let Message::Halo { block, .. }
            | Message::FpRows { block, .. }
            | Message::NaRows { block, .. }
            | Message::BatchRows { block, .. } = &m
            {
                assert!(block.validate().is_ok(), "generated blocks are well-formed");
                saw_empty_block |= block.ids.is_empty();
            }
        }
        assert_eq!(tags.len(), 10, "all wire variants generated: {tags:?}");
        assert!(saw_empty_block, "empty halo shape must be exercised");
    }

    #[test]
    fn pair_combinator() {
        let s = Pair(CsrStrategy::default(), TensorStrategy::default());
        let mut rng = Pcg32::seeded(5);
        let (csr, t) = s.generate(&mut rng);
        assert!(csr.validate().is_ok());
        assert!(t.rows() > 0);
        // shrinks come from both sides
        let shrinks = s.shrink(&(csr, t));
        assert!(!shrinks.is_empty());
    }
}
