//! Staged backward pass mirroring the forward stages of
//! [`crate::engine::stages`].
//!
//! The training-characterization companion work (arxiv 2407.11790)
//! shows the backward pass has its own stage mix: grad-SpMM over the
//! *transposed* sub-CSR dominates, with attention backward adding
//! SDDMM-shaped kernels. Every backward kernel here is expressed in the
//! same substrate as the forward — `sgemm`(+`_tn`/`_nt`) for the dense
//! gradients, `SpMMCsr` over [`Csr::transposed`] sub-CSRs for the
//! aggregation gradients, `SDDMMCoo`/`edge_softmax` for attention
//! backward — so profiles attribute training time with the same kernel
//! taxonomy (DM/TB/EW/DR), and every kernel keeps the serial per-row
//! accumulation order: gradients are **bit-identical at every thread
//! count**.
//!
//! [`Csr::transposed`]: crate::graph::Csr::transposed

use std::collections::BTreeMap;

use crate::engine::stages::{self, segment_sum_edges};
use crate::graph::HeteroGraph;
use crate::kernels::dense::{sgemm, sgemm_bias, sgemm_nt, sgemm_tn, GemmBlocking};
use crate::kernels::elementwise::{
    reduce_rows_mean, rowwise_dot, scale_rows, softmax_vec, unary, BinaryOp, UnaryOp,
};
use crate::kernels::rearrange::{concat_rows, index_select};
use crate::kernels::sparse_ops::{
    edge_softmax, edge_softmax_backward, sddmm_coo, sddmm_edge_dot, spmm_csr,
    transpose_edge_perm, SpmmReduce,
};
use crate::kernels::Ctx;
use crate::models::{ModelId, ModelPlan, ModelWeights};
use crate::tensor::Tensor;
use crate::{Error, Result};

/// Per-subgraph Neighbor Aggregation intermediates saved by the forward
/// pass; the post-activation output itself lives in
/// [`Tape::na_results`].
#[derive(Debug)]
pub enum NaTape {
    /// R-GCN/GCN mean aggregation: nothing beyond topology is needed.
    Mean,
    /// HAN GAT-style attention: per-node attention terms and the edge
    /// softmax output `alpha` (CSR nonzero order).
    Han {
        /// Destination-side attention terms `h_dst · attn_l`.
        s_dst: Vec<f32>,
        /// Source-side attention terms `h_src · attn_r`.
        s_src: Vec<f32>,
        /// Edge softmax weights, CSR nonzero order.
        alpha: Vec<f32>,
    },
    /// MAGNN instance attention: encoded instances, raw (pre-LeakyReLU)
    /// instance scores and the edge softmax output.
    Magnn {
        /// Encoded metapath instances `[nnz, hidden]`.
        enc: Tensor,
        /// Raw instance scores `enc · w` (pre-LeakyReLU), nonzero order.
        scores: Vec<f32>,
        /// Edge softmax weights, CSR nonzero order.
        alpha: Vec<f32>,
    },
}

/// Semantic Aggregation intermediates saved by the forward pass.
#[derive(Debug)]
pub enum SaTape {
    /// GCN passthrough / R-GCN relation sum: no learned parameters.
    Passthrough,
    /// HAN/MAGNN semantic attention pipeline.
    Attention {
        /// Concatenated NA results `[P*N, hidden]`.
        stacked: Tensor,
        /// `tanh(stacked · W + b)`, `[P*N, semantic_dim]`.
        t: Tensor,
        /// Softmax-normalized per-metapath weights, length `P`.
        beta: Vec<f32>,
    },
}

/// Saved activations of one forward pass, enough to run the staged
/// backward without recomputation (the memory-for-compute trade the
/// training characterization measures).
#[derive(Debug)]
pub struct Tape {
    /// Stage-② outputs per node type.
    pub projected: BTreeMap<usize, Tensor>,
    /// Per-subgraph stage-③ intermediates.
    pub na: Vec<NaTape>,
    /// Per-subgraph stage-③ outputs (post-activation).
    pub na_results: Vec<Tensor>,
    /// Stage-④ intermediates.
    pub sa: SaTape,
    /// Final embeddings `[target_count, hidden]`.
    pub output: Tensor,
}

/// Gradient accumulator for one backward pass: weight gradients shaped
/// like the plan's weights ([`ModelWeights::zeros_like`]) plus the
/// intermediate per-type projected-activation gradients that stage-③
/// backward produces and stage-② backward consumes.
#[derive(Debug)]
pub struct Grads {
    /// Weight gradients, same shapes/groups as the plan's weights.
    pub weights: ModelWeights,
    /// `dL/d(projected[ty])`, filled by NA backward, consumed by FP
    /// backward.
    pub d_projected: BTreeMap<usize, Tensor>,
}

impl Grads {
    /// Zeroed accumulator for a plan's weight set.
    pub fn zeros(weights: &ModelWeights) -> Grads {
        Grads { weights: weights.zeros_like(), d_projected: BTreeMap::new() }
    }
}

/// Elementwise `dst += src` (gradient accumulation glue).
fn add_into(dst: &mut Tensor, src: &Tensor) -> Result<()> {
    if dst.shape() != src.shape() {
        return Err(Error::shape(format!(
            "grad accumulate: {:?} += {:?}",
            dst.shape(),
            src.shape()
        )));
    }
    for (d, &s) in dst.as_mut_slice().iter_mut().zip(src.as_slice()) {
        *d += s;
    }
    Ok(())
}

/// Accumulate a per-type activation gradient (first write moves, later
/// writes add).
fn accumulate(map: &mut BTreeMap<usize, Tensor>, ty: usize, t: Tensor) -> Result<()> {
    match map.entry(ty) {
        std::collections::btree_map::Entry::Occupied(mut e) => add_into(e.get_mut(), &t),
        std::collections::btree_map::Entry::Vacant(v) => {
            v.insert(t);
            Ok(())
        }
    }
}

/// `dL/dAgg` from `dL/dOut` through the ELU: `ELU'(x) = 1` for `x ≥ 0`,
/// else `exp(x) = ELU(x) + 1` — recoverable from the saved *output*.
fn elu_backward(d_out: &Tensor, out: &Tensor) -> Tensor {
    let mut g = d_out.clone();
    for (gv, &o) in g.as_mut_slice().iter_mut().zip(out.as_slice()) {
        *gv *= if o >= 0.0 { 1.0 } else { o + 1.0 };
    }
    g
}

/// Forward pass with saved activations: identical kernel sequence to
/// [`stages::feature_projection`] / [`stages::neighbor_aggregation`] /
/// [`stages::semantic_aggregation`] (the output is bit-identical to the
/// inference path), keeping the intermediates the backward needs.
pub fn forward_tape(
    ctx: &mut Ctx,
    plan: &ModelPlan,
    hg: &HeteroGraph,
    blocking: GemmBlocking,
) -> Result<Tape> {
    let projected = stages::feature_projection(ctx, plan, hg, blocking)?;
    let mut na = Vec::with_capacity(plan.num_subgraphs());
    let mut na_results = Vec::with_capacity(plan.num_subgraphs());
    for i in 0..plan.num_subgraphs() {
        let (t, out) = na_forward_tape(ctx, plan, i, &projected)?;
        na.push(t);
        na_results.push(out);
    }
    let (sa, output) = sa_forward_tape(ctx, plan, &na_results, blocking)?;
    Ok(Tape { projected, na, na_results, sa, output })
}

/// Stage-③ forward for one subgraph, saving backward intermediates.
fn na_forward_tape(
    ctx: &mut Ctx,
    plan: &ModelPlan,
    i: usize,
    projected: &BTreeMap<usize, Tensor>,
) -> Result<(NaTape, Tensor)> {
    let sg = &plan.subgraphs.subgraphs[i];
    let h_src = projected
        .get(&sg.src_type)
        .ok_or_else(|| Error::config(format!("NA backward: type {} not projected", sg.src_type)))?;
    match plan.model {
        ModelId::Rgcn | ModelId::Gcn => {
            let out = spmm_csr(ctx, &sg.adj, h_src, None, SpmmReduce::Mean)?;
            Ok((NaTape::Mean, out))
        }
        ModelId::Han => {
            let h_dst = projected.get(&sg.dst_type).unwrap_or(h_src);
            let s_dst = rowwise_dot(ctx, h_dst, &plan.weights.attn_l[i])?;
            let s_src = rowwise_dot(ctx, h_src, &plan.weights.attn_r[i])?;
            let logits = sddmm_coo(ctx, &sg.adj, &s_dst, &s_src, plan.config.leaky_slope)?;
            let alpha = edge_softmax(ctx, &sg.adj, &logits)?;
            let agg = spmm_csr(ctx, &sg.adj, h_src, Some(&alpha), SpmmReduce::Sum)?;
            let out = unary(ctx, &agg, UnaryOp::Elu);
            ctx.arena.give(agg.into_vec());
            Ok((NaTape::Han { s_dst, s_src, alpha }, out))
        }
        ModelId::Magnn => {
            let h_dst = projected.get(&sg.dst_type).unwrap_or(h_src);
            let src_rows: Vec<u32> = sg.adj.indices.clone();
            let mut dst_rows = Vec::with_capacity(sg.adj.nnz());
            for d in 0..sg.adj.n_rows {
                dst_rows.extend(std::iter::repeat_n(d as u32, sg.adj.degree(d)));
            }
            let e_src = index_select(ctx, h_src, &src_rows)?;
            let e_dst = index_select(ctx, h_dst, &dst_rows)?;
            let sum = crate::kernels::elementwise::binary(ctx, &e_src, &e_dst, BinaryOp::Add)?;
            ctx.arena.give(e_src.into_vec());
            ctx.arena.give(e_dst.into_vec());
            let enc = unary(ctx, &sum, UnaryOp::Scale(0.5));
            ctx.arena.give(sum.into_vec());
            let w_col: Vec<f32> = plan.weights.inst_attn[i].as_slice().to_vec();
            let scores = rowwise_dot(ctx, &enc, &w_col)?;
            let scores_t = Tensor::from_vec(scores.len(), 1, scores.clone())?;
            let logits = unary(ctx, &scores_t, UnaryOp::LeakyRelu(plan.config.leaky_slope));
            let alpha = edge_softmax(ctx, &sg.adj, logits.as_slice())?;
            let scaled = scale_rows(ctx, &enc, &alpha)?;
            let agg = segment_sum_edges(ctx, &sg.adj, &scaled)?;
            ctx.arena.give(scaled.into_vec());
            let out = unary(ctx, &agg, UnaryOp::Elu);
            ctx.arena.give(agg.into_vec());
            Ok((NaTape::Magnn { enc, scores, alpha }, out))
        }
    }
}

/// Stage-④ forward saving backward intermediates.
fn sa_forward_tape(
    ctx: &mut Ctx,
    plan: &ModelPlan,
    na_results: &[Tensor],
    blocking: GemmBlocking,
) -> Result<(SaTape, Tensor)> {
    if na_results.is_empty() {
        return Err(Error::config("SA backward: no NA results"));
    }
    match plan.model {
        ModelId::Gcn | ModelId::Rgcn => {
            let out = stages::semantic_aggregation(ctx, plan, na_results, blocking)?;
            Ok((SaTape::Passthrough, out))
        }
        ModelId::Han | ModelId::Magnn => {
            let p = na_results.len();
            let n = na_results[0].rows();
            let refs: Vec<&Tensor> = na_results.iter().collect();
            let stacked = concat_rows(ctx, &refs)?;
            let sem_w = plan
                .weights
                .sem_w
                .as_ref()
                .ok_or_else(|| Error::config("SA backward: no semantic attention weights"))?;
            let sem_q = plan.weights.sem_q.as_ref().unwrap();
            let lin = sgemm_bias(ctx, &stacked, sem_w, &plan.weights.sem_b, blocking)?;
            let t = unary(ctx, &lin, UnaryOp::Tanh);
            ctx.arena.give(lin.into_vec());
            let scores = sgemm(ctx, &t, sem_q, blocking)?;
            let scores_pn = Tensor::from_vec(p, n, scores.as_slice().to_vec())?;
            ctx.arena.give(scores.into_vec());
            let beta_raw = reduce_rows_mean(ctx, &scores_pn);
            let beta = softmax_vec(ctx, &beta_raw);
            let mut row_scale = Vec::with_capacity(p * n);
            for &b in &beta {
                row_scale.extend(std::iter::repeat_n(b, n));
            }
            let scaled = scale_rows(ctx, &stacked, &row_scale)?;
            let out = crate::kernels::elementwise::reduce_grouped_rows(ctx, &scaled, p)?;
            ctx.arena.give(scaled.into_vec());
            Ok((SaTape::Attention { stacked, t, beta }, out))
        }
    }
}

/// Stage-④ backward: from `dL/dOut` to per-subgraph `dL/dNA_i` plus the
/// semantic-attention weight gradients.
pub fn backward_semantic(
    ctx: &mut Ctx,
    plan: &ModelPlan,
    tape: &Tape,
    d_out: &Tensor,
    grads: &mut Grads,
    blocking: GemmBlocking,
) -> Result<Vec<Tensor>> {
    match plan.model {
        ModelId::Gcn => Ok(vec![d_out.clone()]),
        ModelId::Rgcn => {
            // forward summed the relations targeting the output type:
            // those pass dOut through, the others get a zero gradient
            Ok(plan
                .subgraphs
                .subgraphs
                .iter()
                .zip(&tape.na_results)
                .map(|(sg, na)| {
                    if sg.dst_type == plan.target {
                        d_out.clone()
                    } else {
                        Tensor::zeros(na.rows(), na.cols())
                    }
                })
                .collect())
        }
        ModelId::Han | ModelId::Magnn => {
            let SaTape::Attention { stacked, t, beta } = &tape.sa else {
                return Err(Error::config("SA backward: tape missing attention state"));
            };
            let p = tape.na_results.len();
            let n = tape.na_results[0].rows();
            let sem_w = plan.weights.sem_w.as_ref().unwrap();
            let sem_q = plan.weights.sem_q.as_ref().unwrap();

            // out = Σ_i β_i·Z_i  ⇒  dβ_i = ⟨dOut, Z_i⟩_F
            let dbeta: Vec<f32> = tape
                .na_results
                .iter()
                .map(|z| {
                    d_out
                        .as_slice()
                        .iter()
                        .zip(z.as_slice())
                        .map(|(&a, &b)| a * b)
                        .sum::<f32>()
                })
                .collect();
            // softmax backward over the P metapath weights
            let dot: f32 = beta.iter().zip(&dbeta).map(|(&b, &d)| b * d).sum();
            let dbeta_raw: Vec<f32> =
                beta.iter().zip(&dbeta).map(|(&b, &d)| b * (d - dot)).collect();
            // mean backward: score (i, n) contributed 1/N to β_raw_i
            let mut ds = Vec::with_capacity(p * n);
            for &g in &dbeta_raw {
                ds.extend(std::iter::repeat_n(g / n as f32, n));
            }
            let dscores = Tensor::from_vec(p * n, 1, ds)?;

            // scores = T·q  ⇒  dT = dscores·qᵀ, dq = Tᵀ·dscores
            let dt = sgemm_nt(ctx, &dscores, sem_q, blocking)?;
            let dq = sgemm_tn(ctx, t, &dscores, blocking)?;

            // T = tanh(lin)  ⇒  dlin = dT ⊙ (1 − T²)
            let mut dlin = dt;
            for (g, &tv) in dlin.as_mut_slice().iter_mut().zip(t.as_slice()) {
                *g *= 1.0 - tv * tv;
            }

            // lin = stacked·W + b
            let dw = sgemm_tn(ctx, stacked, &dlin, blocking)?;
            let s = dlin.cols();
            let mut db = vec![0.0f32; s];
            for r in 0..dlin.rows() {
                for (bc, &v) in db.iter_mut().zip(dlin.row(r)) {
                    *bc += v;
                }
            }
            let mut dstacked = sgemm_nt(ctx, &dlin, sem_w, blocking)?;
            ctx.arena.give(dlin.into_vec());

            // the direct β-weighted path: block i of dstacked += β_i·dOut
            let h = d_out.cols();
            let dov = d_out.as_slice();
            let dsv = dstacked.as_mut_slice();
            for (i, &b) in beta.iter().enumerate() {
                let block = &mut dsv[i * n * h..(i + 1) * n * h];
                for (g, &v) in block.iter_mut().zip(dov) {
                    *g += b * v;
                }
            }

            add_into(grads.weights.sem_w.as_mut().unwrap(), &dw)?;
            ctx.arena.give(dw.into_vec());
            for (g, &v) in grads.weights.sem_b.iter_mut().zip(&db) {
                *g += v;
            }
            add_into(grads.weights.sem_q.as_mut().unwrap(), &dq)?;
            ctx.arena.give(dq.into_vec());

            (0..p).map(|i| dstacked.slice_rows(i * n, (i + 1) * n)).collect()
        }
    }
}

/// Stage-③ backward for one subgraph: from `dL/dNA_i` to attention
/// weight gradients and `dL/d(projected)` contributions — the
/// grad-SpMM-over-transposed-CSR stage the training characterization
/// identifies as dominant.
pub fn backward_neighbor(
    ctx: &mut Ctx,
    plan: &ModelPlan,
    i: usize,
    tape: &Tape,
    d_na: &Tensor,
    grads: &mut Grads,
    blocking: GemmBlocking,
) -> Result<()> {
    let sg = &plan.subgraphs.subgraphs[i];
    let h_src = tape
        .projected
        .get(&sg.src_type)
        .ok_or_else(|| Error::config(format!("NA backward: type {} not projected", sg.src_type)))?;
    // forward used projected[dst] when present, else fell back to h_src;
    // the dst-side gradient must flow to the same tensor
    let has_dst = tape.projected.contains_key(&sg.dst_type);
    let dst_ty = if has_dst { sg.dst_type } else { sg.src_type };
    let h_dst = if has_dst { &tape.projected[&sg.dst_type] } else { h_src };

    match (&tape.na[i], plan.model) {
        (NaTape::Mean, ModelId::Rgcn | ModelId::Gcn) => {
            // out[d] = (1/deg d)·Σ h_src[s]: grad-SpMM over the transposed
            // sub-CSR, edge weight 1/deg of the original destination
            let adj_t = sg.adj.transposed();
            let w_t: Vec<f32> = adj_t
                .indices
                .iter()
                .map(|&d| 1.0 / sg.adj.degree(d as usize) as f32)
                .collect();
            let dh = spmm_csr(ctx, &adj_t, d_na, Some(&w_t), SpmmReduce::Sum)?;
            accumulate(&mut grads.d_projected, sg.src_type, dh)
        }
        (NaTape::Han { s_dst, s_src, alpha }, ModelId::Han) => {
            let dagg = elu_backward(d_na, &tape.na_results[i]);

            // ① agg = Σ_e α_e·h_src[s_e]: grad w.r.t. h_src is the same
            // weighted SpMM over the transposed CSR (α carried along the
            // edge permutation)
            let adj_t = sg.adj.transposed();
            let perm = transpose_edge_perm(&sg.adj);
            let mut alpha_t = vec![0.0f32; alpha.len()];
            for (e, &slot) in perm.iter().enumerate() {
                alpha_t[slot as usize] = alpha[e];
            }
            let dh_src_spmm = spmm_csr(ctx, &adj_t, &dagg, Some(&alpha_t), SpmmReduce::Sum)?;

            // ② dα_e = ⟨dAgg[d_e], h_src[s_e]⟩ (SDDMM-shaped)
            let e_src = index_select(ctx, h_src, &sg.adj.indices)?;
            let dalpha = sddmm_edge_dot(ctx, &sg.adj, &dagg, &e_src)?;
            ctx.arena.give(e_src.into_vec());

            // ③ softmax backward, then LeakyReLU backward on the raw
            // logit sign (recomputed from the saved attention terms)
            let dlogits = edge_softmax_backward(ctx, &sg.adj, alpha, &dalpha)?;
            let slope = plan.config.leaky_slope;
            let mut ds_dst = vec![0.0f32; sg.adj.n_rows];
            let mut ds_src = vec![0.0f32; sg.adj.n_cols];
            let mut e = 0usize;
            for d in 0..sg.adj.n_rows {
                for &s in sg.adj.row(d) {
                    let z = s_dst[d] + s_src[s as usize];
                    let dz = dlogits[e] * if z >= 0.0 { 1.0 } else { slope };
                    ds_dst[d] += dz;
                    ds_src[s as usize] += dz;
                    e += 1;
                }
            }

            // ④ s = h·a rowwise dots: dh += ds ⊗ a (outer), da = hᵀ·ds
            let h = h_src.cols();
            let ds_dst_t = Tensor::from_vec(sg.adj.n_rows, 1, ds_dst)?;
            let ds_src_t = Tensor::from_vec(sg.adj.n_cols, 1, ds_src)?;
            let al = Tensor::from_vec(1, h, plan.weights.attn_l[i].clone())?;
            let ar = Tensor::from_vec(1, h, plan.weights.attn_r[i].clone())?;
            let dh_dst = sgemm(ctx, &ds_dst_t, &al, blocking)?;
            let mut dh_src = sgemm(ctx, &ds_src_t, &ar, blocking)?;
            let da_l = sgemm_tn(ctx, h_dst, &ds_dst_t, blocking)?;
            let da_r = sgemm_tn(ctx, h_src, &ds_src_t, blocking)?;
            for (g, &v) in grads.weights.attn_l[i].iter_mut().zip(da_l.as_slice()) {
                *g += v;
            }
            for (g, &v) in grads.weights.attn_r[i].iter_mut().zip(da_r.as_slice()) {
                *g += v;
            }
            ctx.arena.give(da_l.into_vec());
            ctx.arena.give(da_r.into_vec());

            add_into(&mut dh_src, &dh_src_spmm)?;
            ctx.arena.give(dh_src_spmm.into_vec());
            accumulate(&mut grads.d_projected, sg.src_type, dh_src)?;
            accumulate(&mut grads.d_projected, dst_ty, dh_dst)
        }
        (NaTape::Magnn { enc, scores, alpha }, ModelId::Magnn) => {
            let dagg = elu_backward(d_na, &tape.na_results[i]);
            let nnz = sg.adj.nnz();

            // ① agg[d] = Σ_e α_e·enc_e: dα_e = ⟨dAgg[d_e], enc_e⟩ and
            // dEnc_e = α_e·dAgg[d_e]
            let dalpha = sddmm_edge_dot(ctx, &sg.adj, &dagg, enc)?;
            let mut dst_rows = Vec::with_capacity(nnz);
            for d in 0..sg.adj.n_rows {
                dst_rows.extend(std::iter::repeat_n(d as u32, sg.adj.degree(d)));
            }
            let gathered = index_select(ctx, &dagg, &dst_rows)?;
            let mut denc = scale_rows(ctx, &gathered, alpha)?;
            ctx.arena.give(gathered.into_vec());

            // ② softmax backward, LeakyReLU backward on saved raw scores
            let dlogits = edge_softmax_backward(ctx, &sg.adj, alpha, &dalpha)?;
            let slope = plan.config.leaky_slope;
            let dscore: Vec<f32> = dlogits
                .iter()
                .zip(scores)
                .map(|(&dl, &sc)| dl * if sc >= 0.0 { 1.0 } else { slope })
                .collect();

            // ③ score_e = enc_e·w: dEnc += dscore ⊗ wᵀ, dw = encᵀ·dscore
            let h = enc.cols();
            let dscore_t = Tensor::from_vec(nnz, 1, dscore)?;
            let w_row = Tensor::from_vec(1, h, plan.weights.inst_attn[i].as_slice().to_vec())?;
            let denc_w = sgemm(ctx, &dscore_t, &w_row, blocking)?;
            add_into(&mut denc, &denc_w)?;
            ctx.arena.give(denc_w.into_vec());
            let dw = sgemm_tn(ctx, enc, &dscore_t, blocking)?;
            add_into(&mut grads.weights.inst_attn[i], &dw)?;
            ctx.arena.give(dw.into_vec());

            // ④ enc_e = ½(h_src[s_e] + h_dst[d_e]): halve, then
            // segment-sum per destination (forward CSR) and per source
            // (transposed CSR, edge gradients permuted along)
            let dhalf = unary(ctx, &denc, UnaryOp::Scale(0.5));
            ctx.arena.give(denc.into_vec());
            let dh_dst = segment_sum_edges(ctx, &sg.adj, &dhalf)?;
            let adj_t = sg.adj.transposed();
            let perm = transpose_edge_perm(&sg.adj);
            let mut inv = vec![0u32; nnz];
            for (e, &slot) in perm.iter().enumerate() {
                inv[slot as usize] = e as u32;
            }
            let dhalf_t = index_select(ctx, &dhalf, &inv)?;
            ctx.arena.give(dhalf.into_vec());
            let dh_src = segment_sum_edges(ctx, &adj_t, &dhalf_t)?;
            ctx.arena.give(dhalf_t.into_vec());

            accumulate(&mut grads.d_projected, sg.src_type, dh_src)?;
            accumulate(&mut grads.d_projected, dst_ty, dh_dst)
        }
        (saved, model) => Err(Error::config(format!(
            "NA backward: tape {saved:?} does not match model {model:?}"
        ))),
    }
}

/// Stage-② backward: per-type weight gradients (`dW = Xᵀ·dH`, sgemm
/// against the gathered input activations) and, for R-GCN, the learned
/// embedding gradients (`dX = dH·Wᵀ`).
pub fn backward_projection(
    ctx: &mut Ctx,
    plan: &ModelPlan,
    hg: &HeteroGraph,
    grads: &mut Grads,
    blocking: GemmBlocking,
) -> Result<()> {
    for (&ty, w) in &plan.weights.proj {
        let Some(dh) = grads.d_projected.get(&ty) else {
            continue; // type projected but unused by any subgraph grad
        };
        let x = plan.weights.embed.get(&ty).unwrap_or_else(|| hg.features(ty));
        let dw = sgemm_tn(ctx, x, dh, blocking)?;
        add_into(grads.weights.proj.get_mut(&ty).unwrap(), &dw)?;
        ctx.arena.give(dw.into_vec());
        if plan.weights.embed.contains_key(&ty) {
            let dx = sgemm_nt(ctx, dh, w, blocking)?;
            add_into(grads.weights.embed.get_mut(&ty).unwrap(), &dx)?;
            ctx.arena.give(dx.into_vec());
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::datasets::{self, DatasetId, DatasetScale};
    use crate::models::{self, ModelConfig};

    fn setup(model: ModelId) -> (HeteroGraph, ModelPlan) {
        let hg = datasets::build(DatasetId::Imdb, &DatasetScale::ci()).unwrap();
        let plan = models::build_plan(model, &hg, &ModelConfig::default()).unwrap();
        (hg, plan)
    }

    #[test]
    fn tape_output_matches_inference_forward_bitwise() {
        for model in [ModelId::Rgcn, ModelId::Han, ModelId::Magnn, ModelId::Gcn] {
            let (hg, plan) = setup(model);
            let blk = GemmBlocking::default();
            let mut ctx = Ctx::default();
            let tape = forward_tape(&mut ctx, &plan, &hg, blk).unwrap();
            let mut ctx2 = Ctx::default();
            let proj = stages::feature_projection(&mut ctx2, &plan, &hg, blk).unwrap();
            let na: Vec<Tensor> = (0..plan.num_subgraphs())
                .map(|i| stages::neighbor_aggregation(&mut ctx2, &plan, i, &proj, blk).unwrap())
                .collect();
            let out = stages::semantic_aggregation(&mut ctx2, &plan, &na, blk).unwrap();
            assert!(
                tape.output.allclose(&out, 0.0, 0.0),
                "{model:?}: tape forward diverged from the inference path"
            );
        }
    }

    #[test]
    fn backward_fills_every_weight_group() {
        for model in [ModelId::Rgcn, ModelId::Han, ModelId::Magnn] {
            let (hg, plan) = setup(model);
            let blk = GemmBlocking::default();
            let mut ctx = Ctx::default();
            let tape = forward_tape(&mut ctx, &plan, &hg, blk).unwrap();
            let mut grads = Grads::zeros(&plan.weights);
            let d_out = Tensor::full(tape.output.rows(), tape.output.cols(), 1e-2);
            let d_na = backward_semantic(&mut ctx, &plan, &tape, &d_out, &mut grads, blk).unwrap();
            assert_eq!(d_na.len(), plan.num_subgraphs());
            for i in 0..plan.num_subgraphs() {
                backward_neighbor(&mut ctx, &plan, i, &tape, &d_na[i], &mut grads, blk).unwrap();
            }
            backward_projection(&mut ctx, &plan, &hg, &mut grads, blk).unwrap();
            // every parameter group sees a nonzero gradient somewhere
            let nonzero = grads
                .weights
                .params()
                .iter()
                .filter(|g| g.iter().any(|&v| v != 0.0))
                .count();
            assert!(
                nonzero >= grads.weights.params().len().saturating_sub(1),
                "{model:?}: only {nonzero} of {} groups touched",
                grads.weights.params().len()
            );
        }
    }

    #[test]
    fn backward_is_bit_identical_across_threads() {
        for model in [ModelId::Rgcn, ModelId::Han, ModelId::Magnn] {
            let (hg, plan) = setup(model);
            let blk = GemmBlocking::default();
            let run = |threads: usize| {
                crate::parallel::with_threads(threads, || {
                    let mut ctx = Ctx::default();
                    let tape = forward_tape(&mut ctx, &plan, &hg, blk).unwrap();
                    let mut grads = Grads::zeros(&plan.weights);
                    let d_out = Tensor::full(tape.output.rows(), tape.output.cols(), 1e-2);
                    let d_na =
                        backward_semantic(&mut ctx, &plan, &tape, &d_out, &mut grads, blk)
                            .unwrap();
                    for i in 0..plan.num_subgraphs() {
                        backward_neighbor(&mut ctx, &plan, i, &tape, &d_na[i], &mut grads, blk)
                            .unwrap();
                    }
                    backward_projection(&mut ctx, &plan, &hg, &mut grads, blk).unwrap();
                    grads
                })
            };
            let serial = run(1);
            let wide = run(4);
            for (a, b) in serial.weights.params().iter().zip(wide.weights.params()) {
                assert_eq!(*a, b, "{model:?}: gradients differ across thread counts");
            }
        }
    }
}
