//! Mini-batch training subsystem: staged backward pass, optimizers and
//! a fused backward kernel schedule.
//!
//! Completes the train/serve lifecycle on top of the inference stack:
//! the forward runs through the same stage kernels (saving a [`Tape`]
//! of activations), the loss is a softmax cross-entropy over a linear
//! classifier head, and the backward walks the stages in reverse —
//! Semantic Aggregation (④), per-subgraph Neighbor Aggregation (③,
//! grad-SpMM over transposed sub-CSRs), Feature Projection (②) — into a
//! [`Grads`] accumulator an [`Optimizer`] applies through
//! `Session::set_weights` (which bumps the reuse-cache generation, so
//! training invalidates served state exactly like any weight swap).
//!
//! The per-relation backward kernel swarm can be dispatched **fused**:
//! adjacent same-name kernels across the per-subgraph backward passes
//! merge into one dispatch per kernel per stage
//! ([`coalesce_events`]) — the mini-batch-training speedup of arxiv
//! 2408.08490, measurable here as a strictly lower dispatch count in
//! [`BatchResult::backward_dispatches`].
//!
//! Determinism: every kernel (forward and backward) keeps serial
//! per-row accumulation order, the batch order is a seeded shuffle, and
//! the optimizer is elementwise — a training epoch is **bit-identical**
//! for a given seed at every thread count.

pub mod backward;
pub mod optim;

pub use backward::{forward_tape, Grads, NaTape, SaTape, Tape};
pub use optim::{Optimizer, OptimizerSpec};

use crate::graph::HeteroGraph;
use crate::kernels::dense::{sgemm, GemmBlocking};
use crate::kernels::dense::{sgemm_nt, sgemm_tn};
use crate::kernels::rearrange::index_select;
use crate::kernels::{Ctx, KernelExec};
use crate::models::{ModelPlan, ModelWeights};
use crate::session::ExecBackend;
use crate::tensor::Tensor;
use crate::util::stats;
use crate::util::Pcg32;
use crate::{Error, Result};

/// Training hyperparameters. The learning rate lives inside
/// [`OptimizerSpec`].
#[derive(Debug, Clone, PartialEq)]
pub struct TrainConfig {
    /// Number of epochs a `fit` runs.
    pub epochs: usize,
    /// Seeds per mini-batch (clamped to the target-type node count).
    pub batch: usize,
    /// Update rule and learning rate.
    pub optimizer: OptimizerSpec,
    /// Seed for weight init, label synthesis and batch shuffling.
    pub seed: u64,
    /// Number of classes of the synthetic node-classification task.
    pub classes: usize,
    /// Fuse the per-relation backward kernel swarm into one dispatch
    /// per kernel per stage.
    pub fused: bool,
}

impl Default for TrainConfig {
    fn default() -> Self {
        TrainConfig {
            epochs: 3,
            batch: 256,
            optimizer: OptimizerSpec::sgd(0.05),
            seed: 0x7A11,
            classes: 4,
            fused: true,
        }
    }
}

impl TrainConfig {
    /// Reject degenerate hyperparameters (zero epochs/batch/classes,
    /// non-positive or non-finite learning rate, momentum outside
    /// `[0, 1)`).
    pub fn validate(&self) -> Result<()> {
        if self.epochs == 0 {
            return Err(Error::config("train: epochs must be >= 1"));
        }
        if self.batch == 0 {
            return Err(Error::config("train: batch size must be >= 1"));
        }
        if self.classes < 2 {
            return Err(Error::config("train: need at least 2 classes"));
        }
        let lr = match self.optimizer {
            OptimizerSpec::Sgd { lr, .. } | OptimizerSpec::Adam { lr, .. } => lr,
        };
        if !lr.is_finite() || lr <= 0.0 {
            return Err(Error::config(format!("train: learning rate {lr} must be positive")));
        }
        if let OptimizerSpec::Sgd { momentum, .. } = self.optimizer {
            if !(0.0..1.0).contains(&momentum) {
                return Err(Error::config(format!(
                    "train: momentum {momentum} must be in [0, 1)"
                )));
            }
        }
        Ok(())
    }
}

/// Driver state for mini-batch training: the classifier head, the
/// optimizer moments and the epoch counter. Built once per `fit` (or
/// via `Session::trainer`) and fed to `Session::train_epoch`.
#[derive(Debug)]
pub struct Trainer {
    pub(crate) config: TrainConfig,
    pub(crate) head: Tensor,
    pub(crate) opt: Optimizer,
    pub(crate) epoch: usize,
}

impl Trainer {
    /// Build a trainer for a model's weight template: a seeded
    /// `[hidden, classes]` classifier head (PCG stream `0x6000`, like
    /// the model's own weight streams) and zeroed optimizer state.
    pub fn new(config: TrainConfig, template: &ModelWeights, hidden: usize) -> Result<Trainer> {
        config.validate()?;
        if hidden == 0 {
            return Err(Error::config("train: hidden dim must be >= 1"));
        }
        let mut rng = Pcg32::new(config.seed, 0x6000);
        let scale = (1.0 / hidden as f32).sqrt();
        let head = Tensor::randn(hidden, config.classes, scale, &mut rng);
        let opt = Optimizer::new(config.optimizer, template, head.len());
        Ok(Trainer { config, head, opt, epoch: 0 })
    }

    /// The training hyperparameters.
    pub fn config(&self) -> &TrainConfig {
        &self.config
    }

    /// The classifier head `[hidden, classes]`.
    pub fn head(&self) -> &Tensor {
        &self.head
    }

    /// Completed epochs.
    pub fn epoch(&self) -> usize {
        self.epoch
    }
}

/// Per-epoch training metrics (loss/accuracy are averaged over the
/// epoch's batches *before* each optimizer step).
#[derive(Debug, Clone, PartialEq)]
pub struct EpochStats {
    /// 1-based epoch number.
    pub epoch: usize,
    /// Mean cross-entropy over the epoch's examples.
    pub loss: f64,
    /// Fraction of examples classified correctly.
    pub accuracy: f64,
    /// Mini-batches executed.
    pub batches: usize,
    /// Examples (seed nodes) consumed.
    pub examples: usize,
    /// Backward-pass kernel dispatches recorded across the epoch
    /// (strictly lower under the fused schedule).
    pub backward_dispatches: usize,
    /// Wall time of the epoch.
    pub epoch_nanos: u64,
}

/// The result of `Session::fit`: one [`EpochStats`] per epoch.
#[derive(Debug, Clone, Default)]
pub struct FitReport {
    /// Per-epoch metrics, in order.
    pub epochs: Vec<EpochStats>,
}

impl FitReport {
    /// Loss of the last epoch (NaN when no epochs ran).
    pub fn final_loss(&self) -> f64 {
        self.epochs.last().map(|e| e.loss).unwrap_or(f64::NAN)
    }

    /// True when the per-epoch loss strictly decreases.
    pub fn monotonic_loss(&self) -> bool {
        self.epochs.windows(2).all(|w| w[1].loss < w[0].loss)
    }
}

/// One mini-batch's forward + loss + staged backward, before the
/// optimizer step.
#[derive(Debug)]
pub struct BatchResult {
    /// Mean cross-entropy over the batch.
    pub loss: f64,
    /// Fraction of the batch classified correctly.
    pub accuracy: f64,
    /// Seeds in the batch.
    pub examples: usize,
    /// Weight gradients (shaped like the executed plan's weights — for
    /// a sampled batch the embedding rows are plan-local; see
    /// [`fold_grads`]).
    pub grads: Grads,
    /// Classifier-head gradient `[hidden, classes]`.
    pub head_grad: Tensor,
    /// Kernel dispatches recorded by the backward stages.
    pub backward_dispatches: usize,
}

/// Deterministic synthetic label for a target node: a pure function of
/// (seed, global node id), so every shard, thread and sampled batch
/// sees the same task.
pub fn synthetic_label(seed: u64, node: u32, classes: usize) -> u32 {
    Pcg32::new(seed, 0x9000 + node as u64).gen_range(classes) as u32
}

/// Merge a backward kernel swarm into one dispatch per kernel name,
/// preserving first-seen order and summing counters/wall time — the
/// fused schedule of arxiv 2408.08490. Gather traces are dropped (a
/// fused dispatch has no single gather stream).
pub fn coalesce_events(events: Vec<KernelExec>) -> Vec<KernelExec> {
    let mut out: Vec<KernelExec> = Vec::new();
    for e in events {
        if let Some(m) = out.iter_mut().find(|m| m.name == e.name) {
            m.counters.merge(&e.counters);
            m.wall_nanos += e.wall_nanos;
        } else {
            out.push(KernelExec { trace: None, ..e });
        }
    }
    out
}

/// Softmax cross-entropy gradient: `dlogits = (softmax(logits) −
/// onehot(label)) / B`, row-serial and f64-stable like the loss.
fn softmax_xent_grad(logits: &Tensor, labels: &[u32]) -> Result<Tensor> {
    let (b, c) = logits.shape();
    if labels.len() != b {
        return Err(Error::shape(format!("{} labels for {b} logit rows", labels.len())));
    }
    let mut out = Tensor::zeros(b, c);
    let inv_b = 1.0 / b as f64;
    for r in 0..b {
        let row = logits.row(r);
        let maxv = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max) as f64;
        let mut denom = 0.0f64;
        for &v in row {
            denom += (v as f64 - maxv).exp();
        }
        let orow = out.row_mut(r);
        for (j, &v) in row.iter().enumerate() {
            let p = (v as f64 - maxv).exp() / denom;
            let y = if labels[r] as usize == j { 1.0 } else { 0.0 };
            orow[j] = ((p - y) * inv_b) as f32;
        }
    }
    Ok(out)
}

/// One mini-batch step, loss included, through a backend's backward
/// stage entry points: forward with saved activations, softmax
/// cross-entropy over the head at `rows`, then staged backward
/// (SA → per-subgraph NA → FP). The per-subgraph NA backward swarm runs
/// into staging contexts and lands in `ctx` either verbatim (`fused =
/// false`) or coalesced to one dispatch per kernel ([`coalesce_events`]).
#[allow(clippy::too_many_arguments)]
pub fn run_batch(
    backend: &dyn ExecBackend,
    ctx: &mut Ctx,
    plan: &ModelPlan,
    hg: &HeteroGraph,
    head: &Tensor,
    rows: &[u32],
    labels: &[u32],
    fused: bool,
) -> Result<BatchResult> {
    if rows.is_empty() || rows.len() != labels.len() {
        return Err(Error::config(format!(
            "train batch: {} rows vs {} labels",
            rows.len(),
            labels.len()
        )));
    }
    let classes = head.cols();
    let blocking = GemmBlocking::default();

    // forward with saved activations, then the classifier head
    let tape = backend.forward_tape(ctx, plan, hg)?;
    let sel = index_select(ctx, &tape.output, rows)?;
    let logits = sgemm(ctx, &sel, head, blocking)?;
    let loss = stats::cross_entropy(logits.as_slice(), classes, labels)?;
    let accuracy = stats::accuracy(logits.as_slice(), classes, labels)?;

    // loss backward into the head and the selected embedding rows
    let dlogits = softmax_xent_grad(&logits, labels)?;
    let head_grad = sgemm_tn(ctx, &sel, &dlogits, blocking)?;
    let d_sel = sgemm_nt(ctx, &dlogits, head, blocking)?;
    ctx.arena.give(sel.into_vec());
    let mut d_out = Tensor::zeros(tape.output.rows(), tape.output.cols());
    for (j, &r) in rows.iter().enumerate() {
        for (o, &v) in d_out.row_mut(r as usize).iter_mut().zip(d_sel.row(j)) {
            *o += v;
        }
    }
    ctx.arena.give(d_sel.into_vec());

    // staged backward; the NA swarm goes through staging contexts so
    // the fused schedule can batch adjacent per-relation grad kernels
    let bwd_start = ctx.events.len();
    let mut grads = Grads::zeros(&plan.weights);
    let d_na = backend.backward_semantic(ctx, plan, &tape, &d_out, &mut grads)?;
    if d_na.len() != plan.num_subgraphs() {
        return Err(Error::config(format!(
            "SA backward returned {} gradients for {} subgraphs",
            d_na.len(),
            plan.num_subgraphs()
        )));
    }
    let mut swarm = Vec::new();
    for (i, d) in d_na.iter().enumerate() {
        let mut sub = backend.make_ctx();
        backend.backward_neighbor(&mut sub, plan, i, &tape, d, &mut grads)?;
        swarm.extend(sub.drain());
    }
    let staged = if fused { coalesce_events(swarm) } else { swarm };
    for e in staged {
        ctx.push(e.name, e.ktype, e.counters, e.wall_nanos, e.trace);
    }
    backend.backward_projection(ctx, plan, hg, &mut grads)?;
    let backward_dispatches = ctx.events.len() - bwd_start;

    Ok(BatchResult {
        loss,
        accuracy,
        examples: rows.len(),
        grads,
        head_grad,
        backward_dispatches,
    })
}

/// Accumulate a batch's weight gradients into full-model-shaped
/// gradients. With `nodes` given (a sampled batch's per-type local→
/// parent id maps), embedding-row gradients scatter onto their parent
/// rows; every other group adds one-to-one.
pub fn fold_grads(
    full: &mut ModelWeights,
    batch: &ModelWeights,
    nodes: Option<&[Vec<u32>]>,
) -> Result<()> {
    fn add(dst: &mut [f32], src: &[f32], what: &str) -> Result<()> {
        if dst.len() != src.len() {
            return Err(Error::shape(format!(
                "fold_grads: {what} {} vs {}",
                dst.len(),
                src.len()
            )));
        }
        for (d, &s) in dst.iter_mut().zip(src) {
            *d += s;
        }
        Ok(())
    }
    for (ty, g) in &batch.proj {
        let dst = full
            .proj
            .get_mut(ty)
            .ok_or_else(|| Error::shape(format!("fold_grads: no proj group for type {ty}")))?;
        add(dst.as_mut_slice(), g.as_slice(), "proj")?;
    }
    for (ty, g) in &batch.embed {
        let dst = full
            .embed
            .get_mut(ty)
            .ok_or_else(|| Error::shape(format!("fold_grads: no embed group for type {ty}")))?;
        match nodes {
            Some(map) => {
                let rows = map.get(*ty).ok_or_else(|| {
                    Error::shape(format!("fold_grads: no node map for type {ty}"))
                })?;
                if rows.len() != g.rows() || dst.cols() != g.cols() {
                    return Err(Error::shape(format!(
                        "fold_grads: embed {}x{} via {} rows into {}x{}",
                        g.rows(),
                        g.cols(),
                        rows.len(),
                        dst.rows(),
                        dst.cols()
                    )));
                }
                for (local, &global) in rows.iter().enumerate() {
                    add(dst.row_mut(global as usize), g.row(local), "embed row")?;
                }
            }
            None => add(dst.as_mut_slice(), g.as_slice(), "embed")?,
        }
    }
    if batch.attn_l.len() != full.attn_l.len() || batch.attn_r.len() != full.attn_r.len() {
        return Err(Error::shape("fold_grads: attention group count mismatch"));
    }
    for (dst, g) in full.attn_l.iter_mut().zip(&batch.attn_l) {
        add(dst, g, "attn_l")?;
    }
    for (dst, g) in full.attn_r.iter_mut().zip(&batch.attn_r) {
        add(dst, g, "attn_r")?;
    }
    for (dst, g) in full.inst_attn.iter_mut().zip(&batch.inst_attn) {
        add(dst.as_mut_slice(), g.as_slice(), "inst_attn")?;
    }
    if let (Some(dst), Some(g)) = (full.sem_w.as_mut(), batch.sem_w.as_ref()) {
        add(dst.as_mut_slice(), g.as_slice(), "sem_w")?;
    }
    add(&mut full.sem_b, &batch.sem_b, "sem_b")?;
    if let (Some(dst), Some(g)) = (full.sem_q.as_mut(), batch.sem_q.as_ref()) {
        add(dst.as_mut_slice(), g.as_slice(), "sem_q")?;
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::{KernelCounters, KernelType};

    fn exec(name: &'static str, nanos: u64) -> KernelExec {
        KernelExec {
            name,
            ktype: KernelType::TopologyBased,
            counters: KernelCounters { flops: 1, bytes_read: 2, bytes_written: 3 },
            wall_nanos: nanos,
            trace: None,
        }
    }

    #[test]
    fn config_validation_rejects_degenerates() {
        assert!(TrainConfig::default().validate().is_ok());
        assert!(TrainConfig { epochs: 0, ..Default::default() }.validate().is_err());
        assert!(TrainConfig { batch: 0, ..Default::default() }.validate().is_err());
        assert!(TrainConfig { classes: 1, ..Default::default() }.validate().is_err());
        for lr in [0.0, -0.1, f32::NAN, f32::INFINITY] {
            let cfg = TrainConfig { optimizer: OptimizerSpec::sgd(lr), ..Default::default() };
            assert!(cfg.validate().is_err(), "lr {lr} must be rejected");
        }
        let cfg = TrainConfig {
            optimizer: OptimizerSpec::Sgd { lr: 0.1, momentum: 1.0 },
            ..Default::default()
        };
        assert!(cfg.validate().is_err());
    }

    #[test]
    fn coalesce_merges_by_name_keeping_order() {
        let merged = coalesce_events(vec![
            exec("SpMMCsr", 10),
            exec("SDDMMCoo", 5),
            exec("SpMMCsr", 7),
            exec("edge_softmax", 1),
            exec("SDDMMCoo", 2),
        ]);
        assert_eq!(
            merged.iter().map(|e| e.name).collect::<Vec<_>>(),
            vec!["SpMMCsr", "SDDMMCoo", "edge_softmax"]
        );
        assert_eq!(merged[0].wall_nanos, 17);
        assert_eq!(merged[0].counters.flops, 2);
        assert_eq!(merged[1].counters.bytes_read, 4);
        assert!(coalesce_events(Vec::new()).is_empty());
    }

    #[test]
    fn synthetic_labels_are_deterministic_and_in_range() {
        for node in 0..200u32 {
            let a = synthetic_label(7, node, 4);
            assert_eq!(a, synthetic_label(7, node, 4));
            assert!(a < 4);
        }
        // different seeds give a different task
        let diff = (0..200u32)
            .filter(|&n| synthetic_label(7, n, 4) != synthetic_label(8, n, 4))
            .count();
        assert!(diff > 0);
    }

    #[test]
    fn softmax_grad_rows_sum_to_zero() {
        let logits = Tensor::from_vec(2, 3, vec![1.0, 2.0, 0.5, -1.0, 0.0, 3.0]).unwrap();
        let g = softmax_xent_grad(&logits, &[1, 2]).unwrap();
        for r in 0..2 {
            let s: f32 = g.row(r).iter().sum();
            assert!(s.abs() < 1e-6, "row {r} sums to {s}");
        }
        // the true-label entry is negative (p − 1 < 0)
        assert!(g.get(0, 1) < 0.0);
        assert!(g.get(1, 2) < 0.0);
        assert!(softmax_xent_grad(&logits, &[0]).is_err());
    }

    #[test]
    fn monotonic_loss_detection() {
        let e = |epoch: usize, loss: f64| EpochStats {
            epoch,
            loss,
            accuracy: 0.0,
            batches: 1,
            examples: 1,
            backward_dispatches: 0,
            epoch_nanos: 0,
        };
        let mut r = FitReport { epochs: vec![e(1, 1.0), e(2, 0.8), e(3, 0.7)] };
        assert!(r.monotonic_loss());
        assert!((r.final_loss() - 0.7).abs() < 1e-12);
        r.epochs.push(e(4, 0.9));
        assert!(!r.monotonic_loss());
        assert!(FitReport::default().final_loss().is_nan());
        assert!(FitReport::default().monotonic_loss());
    }
}
