//! First-order optimizers over the model's weight set.
//!
//! Both optimizers walk the weights, gradients and moment buffers
//! through [`ModelWeights::params_mut`]'s fixed deterministic group
//! order, so a step is a pure elementwise function of (weights, grads,
//! moments) — bit-identical regardless of thread count or batch
//! scheduling.

use crate::models::ModelWeights;
use crate::tensor::Tensor;
use crate::{Error, Result};

/// Which update rule to run.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum OptimizerSpec {
    /// SGD with classical momentum: `v ← μ·v + g`, `w ← w − lr·v`.
    Sgd {
        /// Learning rate.
        lr: f32,
        /// Momentum coefficient μ (0 disables the velocity term).
        momentum: f32,
    },
    /// Adam (Kingma & Ba) with bias-corrected moments.
    Adam {
        /// Learning rate.
        lr: f32,
        /// First-moment decay β₁.
        beta1: f32,
        /// Second-moment decay β₂.
        beta2: f32,
        /// Denominator fuzz ε.
        eps: f32,
    },
}

impl OptimizerSpec {
    /// SGD with the repo's default momentum of 0.9.
    pub fn sgd(lr: f32) -> OptimizerSpec {
        OptimizerSpec::Sgd { lr, momentum: 0.9 }
    }

    /// Adam with the standard (0.9, 0.999, 1e-8) constants.
    pub fn adam(lr: f32) -> OptimizerSpec {
        OptimizerSpec::Adam { lr, beta1: 0.9, beta2: 0.999, eps: 1e-8 }
    }

    /// Parse a CLI name (`sgd` / `adam`).
    pub fn parse(name: &str, lr: f32) -> Result<OptimizerSpec> {
        match name {
            "sgd" => Ok(OptimizerSpec::sgd(lr)),
            "adam" => Ok(OptimizerSpec::adam(lr)),
            other => Err(Error::config(format!(
                "unknown optimizer '{other}' (expected sgd|adam)"
            ))),
        }
    }
}

/// Optimizer state: first/second moment buffers shaped like the model's
/// weights plus the classifier head.
#[derive(Debug)]
pub struct Optimizer {
    spec: OptimizerSpec,
    /// SGD velocity / Adam first moment, per weight group.
    m: ModelWeights,
    /// Adam second moment (unused by SGD).
    v: ModelWeights,
    head_m: Vec<f32>,
    head_v: Vec<f32>,
    /// Step counter for Adam bias correction.
    t: u64,
}

impl Optimizer {
    /// Fresh (zeroed) state for a weight template and head size.
    pub fn new(spec: OptimizerSpec, template: &ModelWeights, head_len: usize) -> Optimizer {
        Optimizer {
            spec,
            m: template.zeros_like(),
            v: template.zeros_like(),
            head_m: vec![0.0; head_len],
            head_v: vec![0.0; head_len],
            t: 0,
        }
    }

    /// The configured update rule.
    pub fn spec(&self) -> OptimizerSpec {
        self.spec
    }

    /// Apply one update step in place.
    ///
    /// `weights`/`grads` and `head`/`head_grad` must be structurally
    /// identical to the template the state was built from.
    pub fn step(
        &mut self,
        weights: &mut ModelWeights,
        head: &mut Tensor,
        grads: &ModelWeights,
        head_grad: &Tensor,
    ) -> Result<()> {
        if head.shape() != head_grad.shape() || head.len() != self.head_m.len() {
            return Err(Error::shape(format!(
                "optimizer: head {:?} vs grad {:?} vs state {}",
                head.shape(),
                head_grad.shape(),
                self.head_m.len()
            )));
        }
        self.t += 1;
        let t = self.t;
        let spec = self.spec;

        let mut w_groups = weights.params_mut();
        let g_groups = grads.params();
        let mut m_groups = self.m.params_mut();
        let mut v_groups = self.v.params_mut();
        if w_groups.len() != g_groups.len()
            || w_groups.len() != m_groups.len()
            || w_groups.iter().zip(&g_groups).any(|(w, g)| w.len() != g.len())
        {
            return Err(Error::shape("optimizer: weight/gradient group mismatch"));
        }
        for (((w, g), m), v) in w_groups
            .iter_mut()
            .zip(&g_groups)
            .zip(m_groups.iter_mut())
            .zip(v_groups.iter_mut())
        {
            update_group(spec, t, w, g, m, v);
        }
        update_group(
            spec,
            t,
            head.as_mut_slice(),
            head_grad.as_slice(),
            &mut self.head_m,
            &mut self.head_v,
        );
        Ok(())
    }
}

/// Elementwise update of one parameter group.
fn update_group(
    spec: OptimizerSpec,
    t: u64,
    w: &mut [f32],
    g: &[f32],
    m: &mut [f32],
    v: &mut [f32],
) {
    match spec {
        OptimizerSpec::Sgd { lr, momentum } => {
            for ((w, &g), m) in w.iter_mut().zip(g).zip(m.iter_mut()) {
                *m = momentum * *m + g;
                *w -= lr * *m;
            }
        }
        OptimizerSpec::Adam { lr, beta1, beta2, eps } => {
            let bc1 = 1.0 - beta1.powi(t as i32);
            let bc2 = 1.0 - beta2.powi(t as i32);
            for (((w, &g), m), v) in w.iter_mut().zip(g).zip(m.iter_mut()).zip(v.iter_mut()) {
                *m = beta1 * *m + (1.0 - beta1) * g;
                *v = beta2 * *v + (1.0 - beta2) * g * g;
                let mhat = *m / bc1;
                let vhat = *v / bc2;
                *w -= lr * mhat / (vhat.sqrt() + eps);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn toy() -> (ModelWeights, Tensor) {
        let mut w = ModelWeights { sem_b: vec![1.0], ..Default::default() };
        w.proj.insert(0, Tensor::full(2, 2, 1.0));
        w.attn_l.push(vec![1.0, 1.0]);
        (w, Tensor::full(2, 3, 0.5))
    }

    #[test]
    fn sgd_momentum_accumulates_velocity() {
        let (mut w, mut head) = toy();
        let mut g = w.zeros_like();
        for group in g.params_mut() {
            group.fill(1.0);
        }
        let hg = Tensor::zeros(2, 3);
        let mut opt = Optimizer::new(OptimizerSpec::Sgd { lr: 0.1, momentum: 0.5 }, &w, head.len());
        opt.step(&mut w, &mut head, &g, &hg).unwrap();
        // v=1, w = 1 - 0.1
        assert!((w.proj[&0].get(0, 0) - 0.9).abs() < 1e-6);
        opt.step(&mut w, &mut head, &g, &hg).unwrap();
        // v = 0.5 + 1 = 1.5, w = 0.9 - 0.15
        assert!((w.proj[&0].get(0, 0) - 0.75).abs() < 1e-6);
        assert!((w.sem_b[0] - 0.75).abs() < 1e-6);
        // zero head grad leaves the head untouched
        assert_eq!(head.get(0, 0), 0.5);
    }

    #[test]
    fn adam_first_step_is_lr_sized() {
        let (mut w, mut head) = toy();
        let mut g = w.zeros_like();
        for group in g.params_mut() {
            group.fill(0.3);
        }
        let hg = Tensor::full(2, 3, 0.3);
        let mut opt = Optimizer::new(OptimizerSpec::adam(0.01), &w, head.len());
        opt.step(&mut w, &mut head, &g, &hg).unwrap();
        // bias-corrected first Adam step ≈ lr for any uniform gradient
        assert!((w.proj[&0].get(0, 0) - (1.0 - 0.01)).abs() < 1e-4);
        assert!((head.get(0, 0) - (0.5 - 0.01)).abs() < 1e-4);
    }

    #[test]
    fn spec_parse_and_mismatch_rejected() {
        assert_eq!(OptimizerSpec::parse("sgd", 0.1).unwrap(), OptimizerSpec::sgd(0.1));
        assert_eq!(OptimizerSpec::parse("adam", 0.1).unwrap(), OptimizerSpec::adam(0.1));
        assert!(OptimizerSpec::parse("lion", 0.1).is_err());

        let (mut w, mut head) = toy();
        let g = w.zeros_like();
        let mut opt = Optimizer::new(OptimizerSpec::sgd(0.1), &w, head.len());
        let bad_head = Tensor::zeros(1, 1);
        assert!(opt.step(&mut w, &mut head, &g, &bad_head).is_err());
        let bad_g = ModelWeights::default();
        let hg = Tensor::zeros(2, 3);
        assert!(opt.step(&mut w, &mut head, &bad_g, &hg).is_err());
    }
}
