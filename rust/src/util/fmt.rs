//! Human-readable formatting of times, byte counts and plain counts used
//! by the report renderers and the bench harness.

/// Format a duration given in nanoseconds, picking a sensible unit.
pub fn human_time(nanos: f64) -> String {
    let abs = nanos.abs();
    if abs < 1e3 {
        format!("{nanos:.1} ns")
    } else if abs < 1e6 {
        format!("{:.2} µs", nanos / 1e3)
    } else if abs < 1e9 {
        format!("{:.2} ms", nanos / 1e6)
    } else {
        format!("{:.3} s", nanos / 1e9)
    }
}

/// Format a byte count with binary units.
pub fn human_bytes(bytes: f64) -> String {
    let abs = bytes.abs();
    if abs < 1024.0 {
        format!("{bytes:.0} B")
    } else if abs < 1024.0 * 1024.0 {
        format!("{:.2} KiB", bytes / 1024.0)
    } else if abs < 1024.0 * 1024.0 * 1024.0 {
        format!("{:.2} MiB", bytes / (1024.0 * 1024.0))
    } else {
        format!("{:.2} GiB", bytes / (1024.0 * 1024.0 * 1024.0))
    }
}

/// Format a plain count with SI suffixes (1.2K, 3.4M, ...).
pub fn human_count(count: f64) -> String {
    let abs = count.abs();
    if abs < 1e3 {
        format!("{count:.0}")
    } else if abs < 1e6 {
        format!("{:.2}K", count / 1e3)
    } else if abs < 1e9 {
        format!("{:.2}M", count / 1e6)
    } else {
        format!("{:.2}G", count / 1e9)
    }
}

/// Left-pad to width (for simple ASCII tables).
pub fn pad_left(s: &str, width: usize) -> String {
    if s.len() >= width {
        s.to_string()
    } else {
        format!("{}{}", " ".repeat(width - s.len()), s)
    }
}

/// Right-pad to width.
pub fn pad_right(s: &str, width: usize) -> String {
    if s.len() >= width {
        s.to_string()
    } else {
        format!("{}{}", s, " ".repeat(width - s.len()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn time_units() {
        assert_eq!(human_time(12.0), "12.0 ns");
        assert_eq!(human_time(1_500.0), "1.50 µs");
        assert_eq!(human_time(2_500_000.0), "2.50 ms");
        assert_eq!(human_time(3_210_000_000.0), "3.210 s");
    }

    #[test]
    fn byte_units() {
        assert_eq!(human_bytes(100.0), "100 B");
        assert_eq!(human_bytes(2048.0), "2.00 KiB");
        assert_eq!(human_bytes(3.0 * 1024.0 * 1024.0), "3.00 MiB");
    }

    #[test]
    fn count_units() {
        assert_eq!(human_count(999.0), "999");
        assert_eq!(human_count(1_200.0), "1.20K");
        assert_eq!(human_count(3_400_000.0), "3.40M");
    }

    #[test]
    fn padding() {
        assert_eq!(pad_left("ab", 4), "  ab");
        assert_eq!(pad_right("ab", 4), "ab  ");
        assert_eq!(pad_left("abcd", 2), "abcd");
    }
}
