//! Minimal JSON parser + writer (the vendored crate set has no serde).
//!
//! Supports the full JSON grammar with the usual Rust niceties omitted:
//! numbers parse to f64, no streaming. Used for the artifact manifest
//! (`artifacts/manifest.json`, produced by `python/compile/aot.py`) and
//! for machine-readable bench reports.

use std::collections::BTreeMap;

use crate::{Error, Result};

/// A parsed JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    /// `null`
    Null,
    /// `true` / `false`
    Bool(bool),
    /// any number
    Num(f64),
    /// string
    Str(String),
    /// array
    Arr(Vec<Json>),
    /// object (sorted keys)
    Obj(BTreeMap<String, Json>),
}

impl Json {
    /// Parse a JSON document.
    pub fn parse(s: &str) -> Result<Json> {
        let mut p = Parser { b: s.as_bytes(), i: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.i != p.b.len() {
            return Err(Error::config(format!("trailing JSON at byte {}", p.i)));
        }
        Ok(v)
    }

    /// Object field access.
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// String value.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    /// Numeric value.
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    /// Numeric value as usize.
    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|f| f as usize)
    }

    /// Array items.
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    /// Serialize (compact).
    pub fn to_string(&self) -> String {
        let mut out = String::new();
        self.write(&mut out);
        out
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    out.push_str(&format!("{}", *n as i64));
                } else {
                    out.push_str(&format!("{n}"));
                }
            }
            Json::Str(s) => write_escaped(s, out),
            Json::Arr(v) => {
                out.push('[');
                for (i, item) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    item.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(k, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

fn write_escaped(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn expect(&mut self, c: u8) -> Result<()> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(Error::config(format!(
                "expected '{}' at byte {}, found {:?}",
                c as char,
                self.i,
                self.peek().map(|b| b as char)
            )))
        }
    }

    fn value(&mut self) -> Result<Json> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            other => Err(Error::config(format!(
                "unexpected JSON byte {other:?} at {}",
                self.i
            ))),
        }
    }

    fn literal(&mut self, word: &str, v: Json) -> Result<Json> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(Error::config(format!("bad literal at byte {}", self.i)))
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.i;
        while let Some(c) = self.peek() {
            if c.is_ascii_digit() || matches!(c, b'-' | b'+' | b'.' | b'e' | b'E') {
                self.i += 1;
            } else {
                break;
            }
        }
        let text = std::str::from_utf8(&self.b[start..self.i])
            .map_err(|_| Error::config("non-utf8 number"))?;
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|e| Error::config(format!("bad number '{text}': {e}")))
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err(Error::config("unterminated string")),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'n') => out.push('\n'),
                        Some(b't') => out.push('\t'),
                        Some(b'r') => out.push('\r'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'u') => {
                            if self.i + 4 >= self.b.len() {
                                return Err(Error::config("truncated \\u escape"));
                            }
                            let hex =
                                std::str::from_utf8(&self.b[self.i + 1..self.i + 5])
                                    .map_err(|_| Error::config("bad \\u escape"))?;
                            let code = u32::from_str_radix(hex, 16)
                                .map_err(|_| Error::config("bad \\u escape"))?;
                            out.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                            self.i += 4;
                        }
                        other => {
                            return Err(Error::config(format!("bad escape {other:?}")))
                        }
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // consume one UTF-8 scalar
                    let s = std::str::from_utf8(&self.b[self.i..])
                        .map_err(|_| Error::config("non-utf8 string"))?;
                    let c = s.chars().next().unwrap();
                    out.push(c);
                    self.i += c.len_utf8();
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.i += 1;
                }
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(items));
                }
                other => {
                    return Err(Error::config(format!("bad array sep {other:?}")))
                }
            }
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.i += 1;
                }
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(map));
                }
                other => {
                    return Err(Error::config(format!("bad object sep {other:?}")))
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_roundtrip() {
        let src = r#"{"name":"han_imdb","inputs":[{"shape":[4278,3066],"name":"x"}],"ok":true,"n":3,"f":1.5,"nil":null}"#;
        let v = Json::parse(src).unwrap();
        assert_eq!(v.get("name").unwrap().as_str(), Some("han_imdb"));
        assert_eq!(v.get("n").unwrap().as_usize(), Some(3));
        assert_eq!(v.get("f").unwrap().as_f64(), Some(1.5));
        assert_eq!(v.get("nil"), Some(&Json::Null));
        let inputs = v.get("inputs").unwrap().as_arr().unwrap();
        let shape = inputs[0].get("shape").unwrap().as_arr().unwrap();
        assert_eq!(shape[0].as_usize(), Some(4278));
        // serialize and reparse
        let re = Json::parse(&v.to_string()).unwrap();
        assert_eq!(re, v);
    }

    #[test]
    fn escapes() {
        let v = Json::parse(r#""a\"b\\c\ndA""#).unwrap();
        assert_eq!(v.as_str(), Some("a\"b\\c\ndA"));
        let out = Json::Str("x\"y\n".into()).to_string();
        assert_eq!(out, r#""x\"y\n""#);
    }

    #[test]
    fn whitespace_tolerant() {
        let v = Json::parse("  {\n\t\"a\" : [ 1 , 2 ] }\r\n").unwrap();
        assert_eq!(v.get("a").unwrap().as_arr().unwrap().len(), 2);
    }

    #[test]
    fn errors_reported() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("tru").is_err());
        assert!(Json::parse("{} extra").is_err());
        assert!(Json::parse("\"unterminated").is_err());
    }

    #[test]
    fn numbers() {
        assert_eq!(Json::parse("-3.5e2").unwrap().as_f64(), Some(-350.0));
        assert_eq!(Json::parse("0").unwrap().as_usize(), Some(0));
        // integral floats serialize without decimal point
        assert_eq!(Json::Num(42.0).to_string(), "42");
        assert_eq!(Json::Num(1.25).to_string(), "1.25");
    }

    #[test]
    fn unicode_passthrough() {
        let v = Json::parse("\"métapath→\"").unwrap();
        assert_eq!(v.as_str(), Some("métapath→"));
    }
}
