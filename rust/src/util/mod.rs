//! Small self-contained utilities: deterministic RNG, statistics helpers,
//! human-readable formatting. The offline vendor set has no `rand`,
//! `statrs` or similar, so these are hand-rolled and unit-tested here.

pub mod fmt;
pub mod json;
pub mod rng;
pub mod stats;

pub use fmt::{human_bytes, human_count, human_time};
pub use json::Json;
pub use rng::Pcg32;
pub use stats::{QuantileSketch, Summary};

/// Round `x` up to the next multiple of `m` (m > 0).
#[inline]
pub fn round_up(x: usize, m: usize) -> usize {
    debug_assert!(m > 0);
    x.div_ceil(m) * m
}

/// Integer ceiling division.
#[inline]
pub fn ceil_div(a: usize, b: usize) -> usize {
    debug_assert!(b > 0);
    a.div_ceil(b)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn round_up_basic() {
        assert_eq!(round_up(0, 8), 0);
        assert_eq!(round_up(1, 8), 8);
        assert_eq!(round_up(8, 8), 8);
        assert_eq!(round_up(9, 8), 16);
    }

    #[test]
    fn ceil_div_basic() {
        assert_eq!(ceil_div(0, 4), 0);
        assert_eq!(ceil_div(1, 4), 1);
        assert_eq!(ceil_div(4, 4), 1);
        assert_eq!(ceil_div(5, 4), 2);
    }
}
