//! Deterministic pseudo-random number generation.
//!
//! The vendored crate set has no `rand`, so dataset synthesis and the
//! property-testing framework use this small, well-known PCG32 generator
//! (O'Neill 2014) seeded through splitmix64. Determinism matters: the
//! synthetic IMDB/ACM/DBLP graphs must be bit-identical across runs so
//! that benchmark numbers are comparable run-to-run.

/// splitmix64 — used to expand a user seed into PCG state.
#[inline]
pub fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// PCG32 (XSH-RR variant): 64-bit state, 32-bit output.
#[derive(Debug, Clone)]
pub struct Pcg32 {
    state: u64,
    inc: u64,
}

impl Pcg32 {
    /// Create a generator from a seed and a stream id. Different stream
    /// ids give statistically independent sequences for the same seed.
    pub fn new(seed: u64, stream: u64) -> Self {
        let mut sm = seed;
        let init_state = splitmix64(&mut sm);
        let mut smi = stream.wrapping_mul(0x9E37_79B9_7F4A_7C15).wrapping_add(seed);
        // standard PCG stream selection: odd increment, full 63 bits of
        // stream entropy (a plain `| 1` can collide adjacent streams)
        let init_inc = (splitmix64(&mut sm) ^ splitmix64(&mut smi)) << 1 | 1;
        let mut rng = Pcg32 { state: 0, inc: init_inc };
        rng.next_u32();
        rng.state = rng.state.wrapping_add(init_state);
        rng.next_u32();
        rng
    }

    /// Seed-only constructor (stream 0).
    pub fn seeded(seed: u64) -> Self {
        Self::new(seed, 0)
    }

    /// Next 32 random bits.
    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        let old = self.state;
        self.state = old
            .wrapping_mul(6_364_136_223_846_793_005)
            .wrapping_add(self.inc);
        let xorshifted = (((old >> 18) ^ old) >> 27) as u32;
        let rot = (old >> 59) as u32;
        xorshifted.rotate_right(rot)
    }

    /// Next 64 random bits.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        ((self.next_u32() as u64) << 32) | self.next_u32() as u64
    }

    /// Uniform in `[0, bound)` using Lemire's unbiased method.
    #[inline]
    pub fn gen_range(&mut self, bound: usize) -> usize {
        debug_assert!(bound > 0);
        let bound = bound as u64;
        // 64-bit multiply-shift; bias is < 2^-32, negligible for synthesis.
        ((self.next_u64() as u128 * bound as u128) >> 64) as usize
    }

    /// Uniform f32 in `[0, 1)`.
    #[inline]
    pub fn gen_f32(&mut self) -> f32 {
        (self.next_u32() >> 8) as f32 * (1.0 / (1u32 << 24) as f32)
    }

    /// Uniform f64 in `[0, 1)`.
    #[inline]
    pub fn gen_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Standard normal via Box–Muller (one value per call; simple > fast).
    pub fn gen_normal(&mut self) -> f32 {
        let u1 = self.gen_f64().max(1e-12);
        let u2 = self.gen_f64();
        ((-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()) as f32
    }

    /// Sample from Zipf-like power-law over `[0, n)` with exponent `alpha`
    /// via inverse-CDF on a precomputed table is overkill here; we use the
    /// standard approximate transform `floor(n * u^(1/(1-alpha)))` variant
    /// that yields heavy-tailed degrees appropriate for graph synthesis.
    pub fn gen_powerlaw(&mut self, n: usize, alpha: f64) -> usize {
        debug_assert!(n > 0);
        debug_assert!(alpha > 1.0);
        let u = self.gen_f64().max(1e-12);
        // Pareto-ish: x = u^(-1/(alpha-1)) in [1, inf); fold into [0, n).
        let x = u.powf(-1.0 / (alpha - 1.0)) - 1.0;
        let idx = x as usize;
        idx.min(n - 1)
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.gen_range(i + 1);
            xs.swap(i, j);
        }
    }

    /// Choose `k` distinct indices from `[0, n)` (k <= n), sorted.
    pub fn choose_distinct(&mut self, n: usize, k: usize) -> Vec<usize> {
        debug_assert!(k <= n);
        if k * 3 > n {
            // dense: shuffle a full index vector
            let mut idx: Vec<usize> = (0..n).collect();
            self.shuffle(&mut idx);
            idx.truncate(k);
            idx.sort_unstable();
            idx
        } else {
            // sparse: rejection sample
            let mut seen = std::collections::BTreeSet::new();
            while seen.len() < k {
                seen.insert(self.gen_range(n));
            }
            seen.into_iter().collect()
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Pcg32::new(42, 7);
        let mut b = Pcg32::new(42, 7);
        for _ in 0..100 {
            assert_eq!(a.next_u32(), b.next_u32());
        }
    }

    #[test]
    fn streams_differ() {
        let mut a = Pcg32::new(42, 0);
        let mut b = Pcg32::new(42, 1);
        let same = (0..64).filter(|_| a.next_u32() == b.next_u32()).count();
        assert!(same < 4, "independent streams should rarely collide");
    }

    #[test]
    fn range_bounds() {
        let mut rng = Pcg32::seeded(1);
        for _ in 0..1000 {
            let v = rng.gen_range(13);
            assert!(v < 13);
        }
    }

    #[test]
    fn f32_unit_interval() {
        let mut rng = Pcg32::seeded(2);
        let mut sum = 0.0f64;
        for _ in 0..10_000 {
            let v = rng.gen_f32();
            assert!((0.0..1.0).contains(&v));
            sum += v as f64;
        }
        let mean = sum / 10_000.0;
        assert!((mean - 0.5).abs() < 0.02, "mean {mean} far from 0.5");
    }

    #[test]
    fn normal_moments() {
        let mut rng = Pcg32::seeded(3);
        let n = 20_000;
        let xs: Vec<f64> = (0..n).map(|_| rng.gen_normal() as f64).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.05, "mean {mean}");
        assert!((var - 1.0).abs() < 0.1, "var {var}");
    }

    #[test]
    fn powerlaw_heavy_tail() {
        let mut rng = Pcg32::seeded(4);
        let n = 1000;
        let samples: Vec<usize> = (0..50_000).map(|_| rng.gen_powerlaw(n, 2.2)).collect();
        let zeros = samples.iter().filter(|&&x| x == 0).count();
        let tail = samples.iter().filter(|&&x| x > 100).count();
        assert!(zeros > samples.len() / 3, "mode should be at 0, got {zeros}");
        assert!(tail > 0, "tail should be populated");
    }

    #[test]
    fn choose_distinct_properties() {
        let mut rng = Pcg32::seeded(5);
        for (n, k) in [(10, 10), (100, 3), (50, 25), (1, 1), (7, 0)] {
            let picked = rng.choose_distinct(n, k);
            assert_eq!(picked.len(), k);
            assert!(picked.windows(2).all(|w| w[0] < w[1]), "sorted+distinct");
            assert!(picked.iter().all(|&i| i < n));
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut rng = Pcg32::seeded(6);
        let mut xs: Vec<usize> = (0..100).collect();
        rng.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
    }
}
