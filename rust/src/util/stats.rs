//! Statistics helpers used by the bench harness and the profiler:
//! mean / median / percentiles / MAD over timing samples.

/// Summary statistics over a sample of f64 values (e.g. nanoseconds).
#[derive(Debug, Clone, PartialEq)]
pub struct Summary {
    /// Number of samples.
    pub n: usize,
    /// Arithmetic mean.
    pub mean: f64,
    /// Minimum.
    pub min: f64,
    /// Maximum.
    pub max: f64,
    /// Median (p50).
    pub median: f64,
    /// 5th percentile.
    pub p05: f64,
    /// 95th percentile.
    pub p95: f64,
    /// Sample standard deviation.
    pub stddev: f64,
    /// Median absolute deviation (robust spread).
    pub mad: f64,
}

impl Summary {
    /// Compute summary statistics. Returns a zeroed summary for an empty
    /// sample (callers treat `n == 0` as "no data").
    pub fn of(samples: &[f64]) -> Summary {
        let n = samples.len();
        if n == 0 {
            return Summary {
                n: 0,
                mean: 0.0,
                min: 0.0,
                max: 0.0,
                median: 0.0,
                p05: 0.0,
                p95: 0.0,
                stddev: 0.0,
                mad: 0.0,
            };
        }
        let mut sorted: Vec<f64> = samples.to_vec();
        sorted.sort_by(|a, b| a.partial_cmp(b).expect("NaN in samples"));
        let mean = sorted.iter().sum::<f64>() / n as f64;
        let var = if n > 1 {
            sorted.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / (n - 1) as f64
        } else {
            0.0
        };
        let median = percentile_sorted(&sorted, 50.0);
        let mut devs: Vec<f64> = sorted.iter().map(|x| (x - median).abs()).collect();
        devs.sort_by(|a, b| a.partial_cmp(b).unwrap());
        Summary {
            n,
            mean,
            min: sorted[0],
            max: sorted[n - 1],
            median,
            p05: percentile_sorted(&sorted, 5.0),
            p95: percentile_sorted(&sorted, 95.0),
            stddev: var.sqrt(),
            mad: percentile_sorted(&devs, 50.0),
        }
    }
}

/// Linear-interpolated percentile over a pre-sorted slice.
pub fn percentile_sorted(sorted: &[f64], pct: f64) -> f64 {
    if sorted.is_empty() {
        return 0.0;
    }
    if sorted.len() == 1 {
        return sorted[0];
    }
    let rank = (pct / 100.0) * (sorted.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    let frac = rank - lo as f64;
    sorted[lo] * (1.0 - frac) + sorted[hi] * frac
}

/// Geometric mean of strictly-positive values (0.0 for empty input).
pub fn geomean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let log_sum: f64 = xs.iter().map(|x| x.max(1e-300).ln()).sum();
    (log_sum / xs.len() as f64).exp()
}

/// Pearson correlation coefficient of two equal-length samples.
pub fn pearson(xs: &[f64], ys: &[f64]) -> f64 {
    assert_eq!(xs.len(), ys.len());
    let n = xs.len() as f64;
    if xs.len() < 2 {
        return 0.0;
    }
    let mx = xs.iter().sum::<f64>() / n;
    let my = ys.iter().sum::<f64>() / n;
    let mut cov = 0.0;
    let mut vx = 0.0;
    let mut vy = 0.0;
    for (x, y) in xs.iter().zip(ys) {
        cov += (x - mx) * (y - my);
        vx += (x - mx) * (x - mx);
        vy += (y - my) * (y - my);
    }
    if vx == 0.0 || vy == 0.0 {
        return 0.0;
    }
    cov / (vx.sqrt() * vy.sqrt())
}

/// Gini coefficient of a non-negative sample in `[0, 1)`: 0 = perfectly
/// equal, →1 = one element holds everything. Returns 0 for empty,
/// single-element or all-zero samples. The paper's NA load-imbalance
/// observation is exactly high Gini over destination-vertex degrees; the
/// partitioner ([`crate::partition`]) exists to flatten it across shards.
pub fn gini(xs: &[f64]) -> f64 {
    let n = xs.len();
    if n < 2 {
        return 0.0;
    }
    let mut sorted: Vec<f64> = xs.to_vec();
    sorted.sort_by(|a, b| a.partial_cmp(b).expect("NaN in samples"));
    let total: f64 = sorted.iter().sum();
    if total <= 0.0 {
        return 0.0;
    }
    let weighted: f64 =
        sorted.iter().enumerate().map(|(i, &x)| (i + 1) as f64 * x).sum();
    (2.0 * weighted) / (n as f64 * total) - (n as f64 + 1.0) / n as f64
}

/// Degree-skew summary of one node population — the load-imbalance
/// fingerprint of the Neighbor Aggregation stage (paper §4.2/Obs 4:
/// skewed destination degrees serialize the dominant stage).
#[derive(Debug, Clone, PartialEq)]
pub struct DegreeSkew {
    /// Population size.
    pub n: usize,
    /// Mean degree.
    pub mean: f64,
    /// Maximum degree.
    pub max: f64,
    /// max/mean ratio (1.0 = flat; large = a few hub vertices dominate).
    pub max_mean_ratio: f64,
    /// Gini coefficient of the degrees.
    pub gini: f64,
}

/// Compute the degree-skew summary of a degree sample.
pub fn degree_skew(degrees: &[f64]) -> DegreeSkew {
    let n = degrees.len();
    let mean = if n > 0 { degrees.iter().sum::<f64>() / n as f64 } else { 0.0 };
    let max = degrees.iter().fold(0.0f64, |a, &b| a.max(b));
    DegreeSkew {
        n,
        mean,
        max,
        max_mean_ratio: if mean > 0.0 { max / mean } else { 0.0 },
        gini: gini(degrees),
    }
}

/// Ordinary least squares fit `y = a + b*x`; returns `(a, b, r2)`.
pub fn ols(xs: &[f64], ys: &[f64]) -> (f64, f64, f64) {
    assert_eq!(xs.len(), ys.len());
    let n = xs.len() as f64;
    assert!(xs.len() >= 2, "need at least 2 points for OLS");
    let mx = xs.iter().sum::<f64>() / n;
    let my = ys.iter().sum::<f64>() / n;
    let mut sxy = 0.0;
    let mut sxx = 0.0;
    let mut syy = 0.0;
    for (x, y) in xs.iter().zip(ys) {
        sxy += (x - mx) * (y - my);
        sxx += (x - mx) * (x - mx);
        syy += (y - my) * (y - my);
    }
    let b = if sxx == 0.0 { 0.0 } else { sxy / sxx };
    let a = my - b * mx;
    let r2 = if syy == 0.0 { 1.0 } else { (sxy * sxy) / (sxx * syy) };
    (a, b, r2)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_basics() {
        let s = Summary::of(&[1.0, 2.0, 3.0, 4.0, 5.0]);
        assert_eq!(s.n, 5);
        assert!((s.mean - 3.0).abs() < 1e-12);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 5.0);
        assert!((s.median - 3.0).abs() < 1e-12);
    }

    #[test]
    fn summary_empty_and_single() {
        assert_eq!(Summary::of(&[]).n, 0);
        let s = Summary::of(&[7.0]);
        assert_eq!(s.median, 7.0);
        assert_eq!(s.stddev, 0.0);
    }

    #[test]
    fn percentile_interp() {
        let xs = [0.0, 10.0];
        assert!((percentile_sorted(&xs, 50.0) - 5.0).abs() < 1e-12);
        assert!((percentile_sorted(&xs, 0.0) - 0.0).abs() < 1e-12);
        assert!((percentile_sorted(&xs, 100.0) - 10.0).abs() < 1e-12);
    }

    #[test]
    fn geomean_powers() {
        let g = geomean(&[1.0, 100.0]);
        assert!((g - 10.0).abs() < 1e-9);
    }

    #[test]
    fn pearson_perfect() {
        let xs = [1.0, 2.0, 3.0];
        let ys = [2.0, 4.0, 6.0];
        assert!((pearson(&xs, &ys) - 1.0).abs() < 1e-12);
        let yneg = [6.0, 4.0, 2.0];
        assert!((pearson(&xs, &yneg) + 1.0).abs() < 1e-12);
    }

    #[test]
    fn ols_line() {
        let xs = [0.0, 1.0, 2.0, 3.0];
        let ys = [1.0, 3.0, 5.0, 7.0]; // y = 1 + 2x
        let (a, b, r2) = ols(&xs, &ys);
        assert!((a - 1.0).abs() < 1e-12);
        assert!((b - 2.0).abs() < 1e-12);
        assert!((r2 - 1.0).abs() < 1e-12);
    }

    #[test]
    fn gini_bounds_and_known_values() {
        assert_eq!(gini(&[]), 0.0);
        assert_eq!(gini(&[5.0]), 0.0);
        assert_eq!(gini(&[0.0, 0.0, 0.0]), 0.0);
        assert!(gini(&[1.0, 1.0, 1.0, 1.0]).abs() < 1e-12, "equal sample is 0");
        // one element holds everything: G = (n-1)/n
        let g = gini(&[0.0, 0.0, 0.0, 10.0]);
        assert!((g - 0.75).abs() < 1e-12, "got {g}");
        // order-invariant
        assert!((gini(&[3.0, 1.0, 2.0]) - gini(&[1.0, 2.0, 3.0])).abs() < 1e-12);
    }

    #[test]
    fn degree_skew_summarizes() {
        let s = degree_skew(&[1.0, 1.0, 1.0, 9.0]);
        assert_eq!(s.n, 4);
        assert!((s.mean - 3.0).abs() < 1e-12);
        assert_eq!(s.max, 9.0);
        assert!((s.max_mean_ratio - 3.0).abs() < 1e-12);
        assert!(s.gini > 0.0 && s.gini < 1.0);
        let empty = degree_skew(&[]);
        assert_eq!(empty.n, 0);
        assert_eq!(empty.max_mean_ratio, 0.0);
    }

    #[test]
    fn mad_robust_to_outlier() {
        let s = Summary::of(&[1.0, 1.0, 1.0, 1.0, 1000.0]);
        assert!(s.mad < 1.0, "MAD should ignore the outlier, got {}", s.mad);
        assert!(s.stddev > 100.0, "stddev should see the outlier");
    }
}
