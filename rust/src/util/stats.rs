//! Statistics helpers used by the bench harness and the profiler
//! (mean / median / percentiles / MAD over timing samples) plus the
//! classification metrics the training subsystem reports.

use crate::{Error, Result};

/// Summary statistics over a sample of f64 values (e.g. nanoseconds).
#[derive(Debug, Clone, PartialEq)]
pub struct Summary {
    /// Number of samples.
    pub n: usize,
    /// Arithmetic mean.
    pub mean: f64,
    /// Minimum.
    pub min: f64,
    /// Maximum.
    pub max: f64,
    /// Median (p50).
    pub median: f64,
    /// 5th percentile.
    pub p05: f64,
    /// 95th percentile.
    pub p95: f64,
    /// Sample standard deviation.
    pub stddev: f64,
    /// Median absolute deviation (robust spread).
    pub mad: f64,
}

impl Summary {
    /// Compute summary statistics. Returns a zeroed summary for an empty
    /// sample (callers treat `n == 0` as "no data").
    pub fn of(samples: &[f64]) -> Summary {
        let n = samples.len();
        if n == 0 {
            return Summary {
                n: 0,
                mean: 0.0,
                min: 0.0,
                max: 0.0,
                median: 0.0,
                p05: 0.0,
                p95: 0.0,
                stddev: 0.0,
                mad: 0.0,
            };
        }
        let mut sorted: Vec<f64> = samples.to_vec();
        sorted.sort_by(|a, b| a.partial_cmp(b).expect("NaN in samples"));
        let mean = sorted.iter().sum::<f64>() / n as f64;
        let var = if n > 1 {
            sorted.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / (n - 1) as f64
        } else {
            0.0
        };
        let median = percentile_sorted(&sorted, 50.0);
        let mut devs: Vec<f64> = sorted.iter().map(|x| (x - median).abs()).collect();
        devs.sort_by(|a, b| a.partial_cmp(b).unwrap());
        Summary {
            n,
            mean,
            min: sorted[0],
            max: sorted[n - 1],
            median,
            p05: percentile_sorted(&sorted, 5.0),
            p95: percentile_sorted(&sorted, 95.0),
            stddev: var.sqrt(),
            mad: percentile_sorted(&devs, 50.0),
        }
    }
}

/// Linear-interpolated percentile over a pre-sorted slice.
pub fn percentile_sorted(sorted: &[f64], pct: f64) -> f64 {
    if sorted.is_empty() {
        return 0.0;
    }
    if sorted.len() == 1 {
        return sorted[0];
    }
    let rank = (pct / 100.0) * (sorted.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    let frac = rank - lo as f64;
    sorted[lo] * (1.0 - frac) + sorted[hi] * frac
}

/// Geometric mean of strictly-positive values (0.0 for empty input).
pub fn geomean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let log_sum: f64 = xs.iter().map(|x| x.max(1e-300).ln()).sum();
    (log_sum / xs.len() as f64).exp()
}

/// Pearson correlation coefficient of two equal-length samples.
pub fn pearson(xs: &[f64], ys: &[f64]) -> f64 {
    assert_eq!(xs.len(), ys.len());
    let n = xs.len() as f64;
    if xs.len() < 2 {
        return 0.0;
    }
    let mx = xs.iter().sum::<f64>() / n;
    let my = ys.iter().sum::<f64>() / n;
    let mut cov = 0.0;
    let mut vx = 0.0;
    let mut vy = 0.0;
    for (x, y) in xs.iter().zip(ys) {
        cov += (x - mx) * (y - my);
        vx += (x - mx) * (x - mx);
        vy += (y - my) * (y - my);
    }
    if vx == 0.0 || vy == 0.0 {
        return 0.0;
    }
    cov / (vx.sqrt() * vy.sqrt())
}

/// Gini coefficient of a non-negative sample in `[0, 1)`: 0 = perfectly
/// equal, →1 = one element holds everything. Returns 0 for empty,
/// single-element or all-zero samples. The paper's NA load-imbalance
/// observation is exactly high Gini over destination-vertex degrees; the
/// partitioner ([`crate::partition`]) exists to flatten it across shards.
pub fn gini(xs: &[f64]) -> f64 {
    let n = xs.len();
    if n < 2 {
        return 0.0;
    }
    let mut sorted: Vec<f64> = xs.to_vec();
    sorted.sort_by(|a, b| a.partial_cmp(b).expect("NaN in samples"));
    let total: f64 = sorted.iter().sum();
    if total <= 0.0 {
        return 0.0;
    }
    let weighted: f64 =
        sorted.iter().enumerate().map(|(i, &x)| (i + 1) as f64 * x).sum();
    (2.0 * weighted) / (n as f64 * total) - (n as f64 + 1.0) / n as f64
}

/// Degree-skew summary of one node population — the load-imbalance
/// fingerprint of the Neighbor Aggregation stage (paper §4.2/Obs 4:
/// skewed destination degrees serialize the dominant stage).
#[derive(Debug, Clone, PartialEq)]
pub struct DegreeSkew {
    /// Population size.
    pub n: usize,
    /// Mean degree.
    pub mean: f64,
    /// Maximum degree.
    pub max: f64,
    /// max/mean ratio (1.0 = flat; large = a few hub vertices dominate).
    pub max_mean_ratio: f64,
    /// Gini coefficient of the degrees.
    pub gini: f64,
}

/// Compute the degree-skew summary of a degree sample.
pub fn degree_skew(degrees: &[f64]) -> DegreeSkew {
    let n = degrees.len();
    let mean = if n > 0 { degrees.iter().sum::<f64>() / n as f64 } else { 0.0 };
    let max = degrees.iter().fold(0.0f64, |a, &b| a.max(b));
    DegreeSkew {
        n,
        mean,
        max,
        max_mean_ratio: if mean > 0.0 { max / mean } else { 0.0 },
        gini: gini(degrees),
    }
}

/// Streaming quantile sketch over `u64` samples (nanoseconds in
/// practice): an HDR-histogram-style log-bucketed counter array with
/// 16 sub-buckets per octave, giving ≤ 6.25% relative error on any
/// reported quantile at O(1) record and merge cost and a fixed ~8 KB
/// footprint. The serving runtime keeps one per priority class so
/// p50/p95/p99 stay cheap under sustained load where a raw sample
/// vector would grow without bound.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct QuantileSketch {
    /// Bucket counters, lazily allocated on first record.
    counts: Vec<u64>,
    n: u64,
    sum: f64,
    min: u64,
    max: u64,
}

/// Sub-bucket resolution: 2^4 = 16 linear sub-buckets per octave.
const SUB_BITS: u32 = 4;
const SUBS: u64 = 1 << SUB_BITS;
/// Total buckets needed to cover the full u64 range at this resolution.
const BUCKETS: usize = ((64 - SUB_BITS as usize) << SUB_BITS) + SUBS as usize;

fn bucket_of(v: u64) -> usize {
    if v < SUBS {
        return v as usize; // exact for tiny values
    }
    let msb = 63 - v.leading_zeros();
    let sub = (v >> (msb - SUB_BITS)) & (SUBS - 1);
    (((msb - SUB_BITS + 1) as usize) << SUB_BITS) + sub as usize
}

fn bucket_low(idx: usize) -> u64 {
    if idx < SUBS as usize {
        return idx as u64;
    }
    let oct = (idx >> SUB_BITS) as u32;
    let sub = (idx & (SUBS as usize - 1)) as u64;
    let msb = oct + SUB_BITS - 1;
    (1u64 << msb) | (sub << (msb - SUB_BITS))
}

impl QuantileSketch {
    /// Empty sketch (no allocation until the first sample).
    pub fn new() -> QuantileSketch {
        QuantileSketch::default()
    }

    /// Record one sample.
    pub fn record(&mut self, v: u64) {
        if self.counts.is_empty() {
            self.counts = vec![0; BUCKETS];
            self.min = u64::MAX;
        }
        self.counts[bucket_of(v)] += 1;
        self.n += 1;
        self.sum += v as f64;
        self.min = self.min.min(v);
        self.max = self.max.max(v);
    }

    /// Number of recorded samples.
    pub fn count(&self) -> u64 {
        self.n
    }

    /// Exact arithmetic mean of the recorded samples (0.0 if empty).
    pub fn mean(&self) -> f64 {
        if self.n == 0 { 0.0 } else { self.sum / self.n as f64 }
    }

    /// Exact minimum recorded sample (0 if empty).
    pub fn min(&self) -> u64 {
        if self.n == 0 { 0 } else { self.min }
    }

    /// Exact maximum recorded sample (0 if empty).
    pub fn max(&self) -> u64 {
        self.max
    }

    /// Estimate the `q`-quantile (`q` in `[0, 1]`), e.g. `quantile(0.99)`
    /// for p99. Returns the midpoint of the bucket holding the rank,
    /// clamped into `[min, max]`; 0 if no samples were recorded.
    pub fn quantile(&self, q: f64) -> u64 {
        if self.n == 0 {
            return 0;
        }
        let q = q.clamp(0.0, 1.0);
        let target = ((q * self.n as f64).ceil() as u64).clamp(1, self.n);
        let mut cum = 0u64;
        for (idx, &c) in self.counts.iter().enumerate() {
            if c == 0 {
                continue;
            }
            cum += c;
            if cum >= target {
                let low = bucket_low(idx);
                let rep = if idx < SUBS as usize {
                    low
                } else {
                    let msb = (idx >> SUB_BITS) as u32 + SUB_BITS - 1;
                    low + (1u64 << (msb - SUB_BITS)) / 2
                };
                return rep.clamp(self.min, self.max);
            }
        }
        self.max
    }

    /// Fold another sketch into this one (counter-wise sum).
    pub fn merge(&mut self, other: &QuantileSketch) {
        if other.n == 0 {
            return;
        }
        if self.counts.is_empty() {
            *self = other.clone();
            return;
        }
        for (a, b) in self.counts.iter_mut().zip(&other.counts) {
            *a += b;
        }
        self.n += other.n;
        self.sum += other.sum;
        self.min = self.min.min(other.min);
        self.max = self.max.max(other.max);
    }
}

/// Ordinary least squares fit `y = a + b*x`; returns `(a, b, r2)`.
pub fn ols(xs: &[f64], ys: &[f64]) -> (f64, f64, f64) {
    assert_eq!(xs.len(), ys.len());
    let n = xs.len() as f64;
    assert!(xs.len() >= 2, "need at least 2 points for OLS");
    let mx = xs.iter().sum::<f64>() / n;
    let my = ys.iter().sum::<f64>() / n;
    let mut sxy = 0.0;
    let mut sxx = 0.0;
    let mut syy = 0.0;
    for (x, y) in xs.iter().zip(ys) {
        sxy += (x - mx) * (y - my);
        sxx += (x - mx) * (x - mx);
        syy += (y - my) * (y - my);
    }
    let b = if sxx == 0.0 { 0.0 } else { sxy / sxx };
    let a = my - b * mx;
    let r2 = if syy == 0.0 { 1.0 } else { (sxy * sxy) / (sxx * syy) };
    (a, b, r2)
}

/// Validate a flat row-major `[rows, classes]` logit buffer against its
/// labels; returns the row count.
fn check_logits(logits: &[f32], classes: usize, labels: &[u32]) -> Result<usize> {
    if classes == 0 || logits.len() % classes != 0 {
        return Err(Error::shape(format!(
            "{} logits do not tile into rows of {classes}",
            logits.len()
        )));
    }
    let rows = logits.len() / classes;
    if rows != labels.len() {
        return Err(Error::shape(format!("{rows} logit rows vs {} labels", labels.len())));
    }
    if let Some(&bad) = labels.iter().find(|&&l| l as usize >= classes) {
        return Err(Error::config(format!("label {bad} out of range for {classes} classes")));
    }
    if rows == 0 {
        return Err(Error::shape("no logit rows"));
    }
    Ok(rows)
}

/// Mean softmax cross-entropy of row-major `[rows, classes]` logits
/// against integer labels, accumulated in f64 with a log-sum-exp per
/// row (numerically stable for any logit scale).
pub fn cross_entropy(logits: &[f32], classes: usize, labels: &[u32]) -> Result<f64> {
    let rows = check_logits(logits, classes, labels)?;
    let mut total = 0.0f64;
    for (r, &label) in labels.iter().enumerate() {
        let row = &logits[r * classes..(r + 1) * classes];
        let maxv = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max) as f64;
        let mut denom = 0.0f64;
        for &v in row {
            denom += (v as f64 - maxv).exp();
        }
        // −log softmax[label] = log Σ exp(z − max) − (z_label − max)
        total += denom.ln() - (row[label as usize] as f64 - maxv);
    }
    Ok(total / rows as f64)
}

/// Fraction of rows whose argmax logit equals the label (ties resolve
/// to the lowest class index, deterministically).
pub fn accuracy(logits: &[f32], classes: usize, labels: &[u32]) -> Result<f64> {
    let rows = check_logits(logits, classes, labels)?;
    let mut correct = 0usize;
    for (r, &label) in labels.iter().enumerate() {
        let row = &logits[r * classes..(r + 1) * classes];
        let mut arg = 0usize;
        for (j, &v) in row.iter().enumerate() {
            if v > row[arg] {
                arg = j;
            }
        }
        if arg == label as usize {
            correct += 1;
        }
    }
    Ok(correct as f64 / rows as f64)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_basics() {
        let s = Summary::of(&[1.0, 2.0, 3.0, 4.0, 5.0]);
        assert_eq!(s.n, 5);
        assert!((s.mean - 3.0).abs() < 1e-12);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 5.0);
        assert!((s.median - 3.0).abs() < 1e-12);
    }

    #[test]
    fn summary_empty_and_single() {
        assert_eq!(Summary::of(&[]).n, 0);
        let s = Summary::of(&[7.0]);
        assert_eq!(s.median, 7.0);
        assert_eq!(s.stddev, 0.0);
    }

    #[test]
    fn percentile_interp() {
        let xs = [0.0, 10.0];
        assert!((percentile_sorted(&xs, 50.0) - 5.0).abs() < 1e-12);
        assert!((percentile_sorted(&xs, 0.0) - 0.0).abs() < 1e-12);
        assert!((percentile_sorted(&xs, 100.0) - 10.0).abs() < 1e-12);
    }

    #[test]
    fn geomean_powers() {
        let g = geomean(&[1.0, 100.0]);
        assert!((g - 10.0).abs() < 1e-9);
    }

    #[test]
    fn pearson_perfect() {
        let xs = [1.0, 2.0, 3.0];
        let ys = [2.0, 4.0, 6.0];
        assert!((pearson(&xs, &ys) - 1.0).abs() < 1e-12);
        let yneg = [6.0, 4.0, 2.0];
        assert!((pearson(&xs, &yneg) + 1.0).abs() < 1e-12);
    }

    #[test]
    fn ols_line() {
        let xs = [0.0, 1.0, 2.0, 3.0];
        let ys = [1.0, 3.0, 5.0, 7.0]; // y = 1 + 2x
        let (a, b, r2) = ols(&xs, &ys);
        assert!((a - 1.0).abs() < 1e-12);
        assert!((b - 2.0).abs() < 1e-12);
        assert!((r2 - 1.0).abs() < 1e-12);
    }

    #[test]
    fn gini_bounds_and_known_values() {
        assert_eq!(gini(&[]), 0.0);
        assert_eq!(gini(&[5.0]), 0.0);
        assert_eq!(gini(&[0.0, 0.0, 0.0]), 0.0);
        assert!(gini(&[1.0, 1.0, 1.0, 1.0]).abs() < 1e-12, "equal sample is 0");
        // one element holds everything: G = (n-1)/n
        let g = gini(&[0.0, 0.0, 0.0, 10.0]);
        assert!((g - 0.75).abs() < 1e-12, "got {g}");
        // order-invariant
        assert!((gini(&[3.0, 1.0, 2.0]) - gini(&[1.0, 2.0, 3.0])).abs() < 1e-12);
    }

    #[test]
    fn degree_skew_summarizes() {
        let s = degree_skew(&[1.0, 1.0, 1.0, 9.0]);
        assert_eq!(s.n, 4);
        assert!((s.mean - 3.0).abs() < 1e-12);
        assert_eq!(s.max, 9.0);
        assert!((s.max_mean_ratio - 3.0).abs() < 1e-12);
        assert!(s.gini > 0.0 && s.gini < 1.0);
        let empty = degree_skew(&[]);
        assert_eq!(empty.n, 0);
        assert_eq!(empty.max_mean_ratio, 0.0);
    }

    #[test]
    fn sketch_empty_and_exact_small_values() {
        let s = QuantileSketch::new();
        assert_eq!(s.count(), 0);
        assert_eq!(s.quantile(0.5), 0);
        assert_eq!(s.mean(), 0.0);
        assert_eq!(s.min(), 0);
        assert_eq!(s.max(), 0);
        // values < 32 land in exact unit buckets
        let mut s = QuantileSketch::new();
        for v in [1u64, 2, 3, 4, 5, 6, 7, 8, 9, 10] {
            s.record(v);
        }
        assert_eq!(s.count(), 10);
        assert_eq!(s.quantile(0.5), 5);
        assert_eq!(s.quantile(1.0), 10);
        assert_eq!(s.quantile(0.0), 1);
        assert_eq!(s.min(), 1);
        assert_eq!(s.max(), 10);
        assert!((s.mean() - 5.5).abs() < 1e-12);
    }

    #[test]
    fn sketch_bucket_roundtrip_brackets_value() {
        // each value must fall inside [bucket_low(idx), next bucket_low)
        let mut v = 1u64;
        for _ in 0..60 {
            for probe in [v, v + v / 3, v + v / 2] {
                let idx = bucket_of(probe);
                assert!(bucket_low(idx) <= probe, "low > {probe}");
                if idx + 1 < BUCKETS {
                    assert!(bucket_low(idx + 1) > probe, "high <= {probe}");
                }
            }
            v = v.saturating_mul(2);
        }
        assert!(bucket_of(u64::MAX) < BUCKETS);
    }

    #[test]
    fn sketch_relative_error_bound() {
        let mut rng = crate::util::Pcg32::seeded(42);
        let mut samples: Vec<u64> = (0..5000)
            .map(|_| 1_000 + (rng.gen_f64() * 50_000_000.0) as u64)
            .collect();
        let mut s = QuantileSketch::new();
        for &v in &samples {
            s.record(v);
        }
        samples.sort_unstable();
        for q in [0.5, 0.95, 0.99] {
            let rank = ((q * samples.len() as f64).ceil() as usize)
                .clamp(1, samples.len());
            let exact = samples[rank - 1] as f64;
            let est = s.quantile(q) as f64;
            let rel = (est - exact).abs() / exact;
            assert!(rel <= 0.0625 + 1e-9, "q={q}: est {est} vs exact {exact} (rel {rel})");
        }
    }

    #[test]
    fn sketch_quantiles_monotone() {
        let mut rng = crate::util::Pcg32::seeded(7);
        let mut s = QuantileSketch::new();
        for _ in 0..1000 {
            s.record((rng.gen_f64() * 1e9) as u64);
        }
        let mut prev = 0u64;
        for i in 0..=20 {
            let q = s.quantile(i as f64 / 20.0);
            assert!(q >= prev, "quantiles must be monotone");
            prev = q;
        }
    }

    #[test]
    fn sketch_merge_matches_combined() {
        let mut rng = crate::util::Pcg32::seeded(9);
        let mut a = QuantileSketch::new();
        let mut b = QuantileSketch::new();
        let mut all = QuantileSketch::new();
        for i in 0..2000 {
            let v = (rng.gen_f64() * 1e8) as u64;
            if i % 2 == 0 { a.record(v) } else { b.record(v) }
            all.record(v);
        }
        let mut merged = a.clone();
        merged.merge(&b);
        assert_eq!(merged, all);
        // merging into an empty sketch adopts the other side
        let mut empty = QuantileSketch::new();
        empty.merge(&all);
        assert_eq!(empty, all);
    }

    #[test]
    fn mad_robust_to_outlier() {
        let s = Summary::of(&[1.0, 1.0, 1.0, 1.0, 1000.0]);
        assert!(s.mad < 1.0, "MAD should ignore the outlier, got {}", s.mad);
        assert!(s.stddev > 100.0, "stddev should see the outlier");
    }

    #[test]
    fn cross_entropy_uniform_and_confident() {
        // uniform logits → ln(C) regardless of labels
        let ce = cross_entropy(&[0.0; 8], 4, &[0, 3]).unwrap();
        assert!((ce - (4.0f64).ln()).abs() < 1e-12, "uniform CE {ce}");
        // strongly correct logits → near-zero loss
        let ce = cross_entropy(&[20.0, 0.0, 0.0, 20.0], 2, &[0, 1]).unwrap();
        assert!(ce < 1e-6, "confident CE {ce}");
        // strongly wrong logits → ≈ the logit margin
        let ce = cross_entropy(&[20.0, 0.0], 2, &[1]).unwrap();
        assert!((ce - 20.0).abs() < 1e-6, "wrong CE {ce}");
        // stable at scales that overflow a naive f32 exp
        let ce = cross_entropy(&[120.0, 0.0], 2, &[0]).unwrap();
        assert!(ce.is_finite() && ce >= 0.0);
    }

    #[test]
    fn accuracy_counts_argmax_matches() {
        let logits = [1.0, 2.0, /* row 1 */ 5.0, -1.0, /* row 2 */ 0.0, 0.0];
        // ties resolve to class 0
        let acc = accuracy(&logits, 2, &[1, 0, 0]).unwrap();
        assert!((acc - 1.0).abs() < 1e-12);
        let acc = accuracy(&logits, 2, &[0, 0, 1]).unwrap();
        assert!((acc - 1.0 / 3.0).abs() < 1e-12);
    }

    #[test]
    fn metric_shape_validation() {
        assert!(cross_entropy(&[1.0, 2.0, 3.0], 2, &[0]).is_err());
        assert!(cross_entropy(&[1.0, 2.0], 2, &[0, 1]).is_err());
        assert!(cross_entropy(&[1.0, 2.0], 2, &[2]).is_err());
        assert!(cross_entropy(&[], 2, &[]).is_err());
        assert!(accuracy(&[1.0], 0, &[]).is_err());
    }
}
