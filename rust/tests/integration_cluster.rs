//! Simulation-cluster integration: the distributed owner-computes
//! executor against the monolithic session, entirely on virtual time.
//!
//! Everything here runs on [`SimTransport`] — no real sockets, no
//! `sleep`, no wall-clock in any assertion. Determinism is the whole
//! contract: the same seed and kill schedule must reproduce the same
//! byte stream, the same frame counts, the same re-placements. The
//! matrix covers bit-identity across models × worker counts × reuse,
//! fault injection (drops, dups, delays, mid-wave kills at every wave
//! index of a serve trace), worker retirement, and the reuse-accounting
//! invariant across a kill/re-place cycle.

use std::sync::Arc;
use std::time::Duration;

use hgnn_char::cluster::{ClusterSpec, FaultSpec};
use hgnn_char::datasets::{DatasetId, DatasetScale};
use hgnn_char::models::ModelId;
use hgnn_char::partition::PartitionSpec;
use hgnn_char::reuse::ReuseSpec;
use hgnn_char::sampler::SamplingSpec;
use hgnn_char::serving::{AsyncServer, ServingConfig, SubmitOpts};
use hgnn_char::session::{Session, SessionBuilder};
use hgnn_char::testutil::VirtualClock;

const RECV: Duration = Duration::from_secs(60);

fn builder(model: ModelId) -> SessionBuilder {
    Session::builder()
        .dataset(DatasetId::Imdb)
        .scale(DatasetScale::ci())
        .model(model)
}

// ------------------------------------------------------- bit-identity

/// The full distributed forward is bit-identical to the monolithic one
/// for every HGNN at 1, 2 and 4 workers: owner-computes sub-CSRs pin
/// the f32 accumulation order, and the wire codec round-trips rows
/// bit-exactly.
#[test]
fn cluster_forward_bit_identical_across_models_and_worker_counts() {
    for model in [ModelId::Rgcn, ModelId::Han, ModelId::Magnn] {
        let baseline = builder(model).build().unwrap().run().unwrap();
        for workers in [1usize, 2, 4] {
            let mut session =
                builder(model).cluster(ClusterSpec::new(workers)).build().unwrap();
            let run = session.run().unwrap();
            assert_eq!(
                run.output.as_slice(),
                baseline.output.as_slice(),
                "{model:?} at {workers} workers is not bit-identical"
            );
            assert_eq!(run.na_results.len(), baseline.na_results.len());
            for (a, b) in run.na_results.iter().zip(&baseline.na_results) {
                assert_eq!(a.as_slice(), b.as_slice());
            }
            let stats = session.cluster_stats().unwrap();
            assert_eq!(stats.waves, 1, "one forward is one wave");
            assert_eq!(stats.retired_workers, 0);
            let t = session.cluster().unwrap().transport_stats();
            assert!(t.bytes > 0, "the forward must actually cross the wire");
        }
    }
}

/// More shards than workers: the coordinator packs K shards onto N
/// workers and the result stays bit-identical to both the monolithic
/// and the in-process sharded run.
#[test]
fn cluster_forward_bit_identical_with_more_shards_than_workers() {
    let baseline = builder(ModelId::Han).build().unwrap().run().unwrap();
    let mut session = builder(ModelId::Han)
        .partition(PartitionSpec::new(4))
        .cluster(ClusterSpec::new(2))
        .build()
        .unwrap();
    let run = session.run().unwrap();
    assert_eq!(run.output.as_slice(), baseline.output.as_slice());
    assert_eq!(session.cluster().unwrap().placement().len(), 4);
}

/// The cluster batch path (serve-style sampled batches grouped by owner
/// shard) is bit-identical to the monolithic `run_batch`, with and
/// without the per-shard reuse caches.
#[test]
fn cluster_batch_path_bit_identical_with_and_without_reuse() {
    let ids: Vec<u32> = (0..24).collect();
    for reuse in [false, true] {
        let mk = |workers: Option<usize>| {
            let mut b = builder(ModelId::Rgcn).sampling(SamplingSpec::uniform(usize::MAX, 1));
            if reuse {
                b = b.reuse(ReuseSpec::rows(1 << 12));
            }
            if let Some(n) = workers {
                b = b.cluster(ClusterSpec::new(n));
            }
            b.build().unwrap()
        };
        let mut plain = mk(None);
        let want_cold = plain.run_batch(&ids).unwrap();
        let want_warm = plain.run_batch(&ids).unwrap();
        assert_eq!(want_cold, want_warm, "reuse substitution must be bit-identical");
        for workers in [1usize, 2, 4] {
            let mut clustered = mk(Some(workers));
            assert_eq!(
                want_cold,
                clustered.run_batch(&ids).unwrap(),
                "cold cluster batch diverged at {workers} workers (reuse={reuse})"
            );
            assert_eq!(
                want_warm,
                clustered.run_batch(&ids).unwrap(),
                "warm cluster batch diverged at {workers} workers (reuse={reuse})"
            );
            assert_eq!(clustered.cluster_stats().unwrap().waves, 2);
            if reuse {
                let stats = clustered.reuse_stats().unwrap();
                assert!(
                    stats.proj_hits > 0,
                    "warm cluster batch must hit the per-shard caches: {stats:?}"
                );
            }
        }
    }
}

// ------------------------------------------------------- determinism

/// Same seed + same fault schedule → byte-identical outputs, identical
/// frame counters, identical modeled reports. This is the acceptance
/// bar for the whole sim: two fresh sessions with `FaultSpec::chaos(7)`
/// must replay the exact same history.
#[test]
fn chaotic_runs_reproduce_exactly_from_the_seed() {
    let mk = || {
        builder(ModelId::Han)
            .cluster(ClusterSpec::new(2).with_fault(FaultSpec::chaos(7)))
            .build()
            .unwrap()
    };
    let (mut a, mut b) = (mk(), mk());
    let (run_a, run_b) = (a.run().unwrap(), b.run().unwrap());
    assert_eq!(run_a.output.as_slice(), run_b.output.as_slice());
    assert_eq!(a.cluster_stats(), b.cluster_stats());
    assert_eq!(
        a.cluster().unwrap().transport_stats(),
        b.cluster().unwrap().transport_stats()
    );
    assert_eq!(a.cluster().unwrap().placement(), b.cluster().unwrap().placement());
    // the schedule report is fully modeled (counters → ns), so it must
    // reproduce verbatim — no raw wall-clock leaks into it
    assert_eq!(run_a.report.summary(), run_b.report.summary());
    // and chaos must not bend the results away from the monolithic run
    let base = builder(ModelId::Han).build().unwrap().run().unwrap();
    assert_eq!(run_a.output.as_slice(), base.output.as_slice());
}

/// Delayed and duplicated halos are deduplicated by `(from, seq)`: a
/// dup/delay-only fault schedule leaves the results untouched while the
/// transport counters prove the faults actually fired.
#[test]
fn duplicated_and_delayed_frames_are_deduplicated() {
    let fault = FaultSpec {
        seed: 11,
        drop: 0.0,
        dup: 0.35,
        delay: 0.35,
        delay_ns: Duration::from_millis(120).as_nanos() as u64,
    };
    let base = builder(ModelId::Magnn).build().unwrap().run().unwrap();
    let mut session = builder(ModelId::Magnn)
        .cluster(ClusterSpec::new(2).with_fault(fault))
        .build()
        .unwrap();
    let run = session.run().unwrap();
    assert_eq!(run.output.as_slice(), base.output.as_slice());
    let t = session.cluster().unwrap().transport_stats();
    assert!(t.duplicated > 0, "dup probability .35 never fired? {t:?}");
    assert!(t.delayed > 0, "delay probability .35 never fired? {t:?}");
    assert_eq!(t.dropped, 0);
    assert_eq!(session.cluster_stats().unwrap().retired_workers, 0);
}

// ---------------------------------------------------------- failures

/// A worker killed *mid-wave* (after a fixed number of sent frames, so
/// the kill lands between a request and its reply) is detected by
/// heartbeat silence; its shards re-place and the wave replays to a
/// bit-identical result.
#[test]
fn mid_wave_kill_recovers_bit_identically() {
    let base = builder(ModelId::Han).build().unwrap().run().unwrap();
    let mut session = builder(ModelId::Han)
        .cluster(ClusterSpec::new(2).kill_after_sends(6, 1))
        .build()
        .unwrap();
    let run = session.run().unwrap();
    assert_eq!(run.output.as_slice(), base.output.as_slice());
    let stats = session.cluster_stats().unwrap();
    assert_eq!(stats.retired_workers, 1, "the kill must be detected, not ridden out");
    assert!(stats.replaced_shards >= 1);
    assert!(session.cluster().unwrap().live_workers().len() == 1);
}

/// Kill one worker at *every* wave index of a 4-wave serve trace: each
/// schedule must converge to the exact rows the no-fault trace (and the
/// monolithic session) produces — the in-flight wave replays on the
/// surviving worker and the later waves run on the new placement.
#[test]
fn kill_at_every_wave_index_of_a_serve_trace_recovers_bit_identically() {
    let waves: Vec<Vec<u32>> = (0..4).map(|w| (w * 8..w * 8 + 8).collect()).collect();
    let mk = |spec: Option<ClusterSpec>| {
        let mut b = builder(ModelId::Rgcn).sampling(SamplingSpec::uniform(usize::MAX, 1));
        if let Some(spec) = spec {
            b = b.cluster(spec);
        }
        b.build().unwrap()
    };
    let mut plain = mk(None);
    let want: Vec<_> = waves.iter().map(|ids| plain.run_batch(ids).unwrap()).collect();
    for kill_wave in 1..=4u64 {
        let mut session = mk(Some(ClusterSpec::new(2).kill_at_wave(kill_wave, 0)));
        for (i, ids) in waves.iter().enumerate() {
            let got = session.run_batch(ids).unwrap();
            assert_eq!(
                got, want[i],
                "wave {} diverged when worker 0 dies at wave {kill_wave}",
                i + 1
            );
        }
        let stats = session.cluster_stats().unwrap();
        assert_eq!(stats.waves, 4);
        assert_eq!(stats.retired_workers, 1, "kill at wave {kill_wave} undetected");
        // every shard ended up on the surviving worker
        assert!(session.cluster().unwrap().placement().iter().all(|&w| w == 1));
    }
}

/// An idle worker that stops heartbeating is retired by the idle pump
/// alone (no wave in flight), and the session keeps serving batches
/// bit-identically afterwards.
#[test]
fn idle_worker_retirement_does_not_fail_later_batches() {
    let ids: Vec<u32> = (0..16).collect();
    let mut plain =
        builder(ModelId::Rgcn).sampling(SamplingSpec::uniform(usize::MAX, 1)).build().unwrap();
    let want = plain.run_batch(&ids).unwrap();
    let mut session = builder(ModelId::Rgcn)
        .sampling(SamplingSpec::uniform(usize::MAX, 1))
        .cluster(ClusterSpec::new(2))
        .build()
        .unwrap();
    assert_eq!(want, session.run_batch(&ids).unwrap());
    // the worker dies while the cluster is idle; only heartbeat silence
    // (pumped on virtual time) reveals it
    let cluster = session.cluster_mut().unwrap();
    cluster.kill_worker(0);
    cluster.run_idle(16).unwrap();
    assert!(!cluster.live_workers().contains(&0), "silent worker not retired");
    assert_eq!(session.cluster_stats().unwrap().retired_workers, 1);
    assert_eq!(want, session.run_batch(&ids).unwrap(), "post-retirement batch diverged");
}

/// `Session::handle_worker_down` — the between-waves control path the
/// async server uses — retires the worker, re-places its shards and
/// keeps the batch results bit-identical.
#[test]
fn handle_worker_down_between_waves_keeps_results_identical() {
    let ids: Vec<u32> = (0..16).collect();
    let mut plain =
        builder(ModelId::Rgcn).sampling(SamplingSpec::uniform(usize::MAX, 1)).build().unwrap();
    let want = plain.run_batch(&ids).unwrap();
    let mut session = builder(ModelId::Rgcn)
        .sampling(SamplingSpec::uniform(usize::MAX, 1))
        .cluster(ClusterSpec::new(2))
        .build()
        .unwrap();
    assert_eq!(want, session.run_batch(&ids).unwrap());
    let moved = session.handle_worker_down(0).unwrap();
    assert!(moved >= 1, "worker 0 owned at least one shard");
    assert_eq!(want, session.run_batch(&ids).unwrap(), "post-re-placement batch diverged");
    // retiring the last survivor is refused, not honored
    assert!(session.handle_worker_down(1).is_err());
}

/// The async server treats worker loss as a between-waves control
/// event: queued requests before and after `report_worker_down` all
/// complete, and the ack reports the re-placed shard count.
#[test]
fn async_server_survives_worker_down_with_queued_requests() {
    let clock = Arc::new(VirtualClock::new());
    let config = ServingConfig { max_batch: 4, ..Default::default() };
    let b = builder(ModelId::Rgcn)
        .sampling(SamplingSpec::uniform(usize::MAX, 1))
        .cluster(ClusterSpec::new(2));
    let server = AsyncServer::start_session_with_clock(config, clock, b);
    let before: Vec<_> =
        (0..4).map(|i| server.submit(&[i], SubmitOpts::default()).unwrap()).collect();
    let ack = server.report_worker_down(0).unwrap();
    let after: Vec<_> =
        (4..8).map(|i| server.submit(&[i], SubmitOpts::default()).unwrap()).collect();
    for rx in before.into_iter().chain(after) {
        let rows = rx.recv_timeout(RECV).unwrap().unwrap();
        assert_eq!(rows.len(), 1);
        assert!(rows[0].iter().all(|v| v.is_finite()));
    }
    let moved = ack.recv_timeout(RECV).unwrap().expect("worker-down ack");
    assert!(moved >= 1, "shards must re-place off the dead worker");
    let stats = server.shutdown();
    assert_eq!(stats.completed, 8, "no queued request may be failed by the retirement");
}

// ---------------------------------------------------- reuse accounting

/// Regression for the `ReuseStats::absorb` double-count: retiring a
/// worker folds its dead lane's counters into the session exactly once,
/// so the aggregate is unchanged by the kill itself and stays monotone
/// as the rebuilt (cold) lane warms back up.
#[test]
fn reuse_counters_survive_a_kill_without_double_counting() {
    let ids: Vec<u32> = (0..24).collect();
    let mut session = builder(ModelId::Rgcn)
        .sampling(SamplingSpec::uniform(usize::MAX, 1))
        .reuse(ReuseSpec::rows(1 << 12))
        .cluster(ClusterSpec::new(2))
        .build()
        .unwrap();
    let want = session.run_batch(&ids).unwrap();
    assert_eq!(want, session.run_batch(&ids).unwrap());
    let before = session.reuse_stats().unwrap();
    assert!(before.proj_hits > 0, "warm repeat must hit: {before:?}");

    // the kill/re-place cycle must not change a single counter: the dead
    // lane is absorbed once and replaced by a zeroed lane
    session.handle_worker_down(0).unwrap();
    let after = session.reuse_stats().unwrap();
    assert_eq!(before, after, "retirement changed the aggregate reuse counters");

    // the replacement lane starts cold for the moved shard, so a repeat
    // adds misses (cold refill) and hits (surviving lane) monotonically
    assert_eq!(want, session.run_batch(&ids).unwrap());
    let warmed = session.reuse_stats().unwrap();
    assert!(warmed.proj_hits >= after.proj_hits);
    assert!(warmed.proj_misses >= after.proj_misses);
    assert!(
        warmed.proj_hits + warmed.proj_misses > after.proj_hits + after.proj_misses,
        "the post-kill batch must perform lookups"
    );
}
