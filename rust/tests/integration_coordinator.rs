//! Schedule-policy integration over real models — numerical
//! equivalence, modeled-makespan ordering, timeline shape (Fig 5c) and
//! the §5 guideline ablations — driven through `Session` with
//! `set_schedule` swapping policies over one set of cached state.

use hgnn_char::datasets::{DatasetId, DatasetScale};
use hgnn_char::models::ModelId;
use hgnn_char::profiler::StageId;
use hgnn_char::session::{SchedulePolicy, Session};

fn session(dataset: DatasetId) -> Session {
    Session::builder()
        .dataset(dataset)
        .scale(DatasetScale::factor(0.25))
        .model(ModelId::Han)
        .build()
        .unwrap()
}

#[test]
fn policies_numerically_equivalent_at_scale() {
    let mut s = session(DatasetId::Dblp);
    let seq = s.run().unwrap();
    for policy in [
        SchedulePolicy::InterSubgraphParallel { workers: 3 },
        SchedulePolicy::FusedSubgraph { workers: 3 },
        SchedulePolicy::BoundAwareMixing { workers: 3 },
    ] {
        s.set_schedule(policy);
        let run = s.run().unwrap();
        assert!(
            run.output.allclose(&seq.output, 1e-3, 1e-4),
            "{}: max diff {}",
            policy.label(),
            run.output.max_abs_diff(&seq.output).unwrap()
        );
    }
}

#[test]
fn inter_subgraph_parallelism_improves_makespan() {
    // Fig 5c observation: NA subgraphs are independent => parallel
    // streams shorten the modeled NA phase.
    let mut s = session(DatasetId::Dblp);
    let seq = s.run().unwrap();
    s.set_schedule(SchedulePolicy::InterSubgraphParallel { workers: 3 });
    let par = s.run().unwrap();
    assert!(
        par.report.modeled_makespan_ns < seq.report.modeled_makespan_ns,
        "parallel {:.0} !< sequential {:.0}",
        par.report.modeled_makespan_ns,
        seq.report.modeled_makespan_ns
    );
    assert!(par.report.speedup > 1.0);
}

#[test]
fn timeline_shows_parallel_na_and_barrier() {
    let mut s = session(DatasetId::Dblp);
    s.set_schedule(SchedulePolicy::InterSubgraphParallel { workers: 3 });
    let par = s.run().unwrap();
    let tl = par.profile.timeline();
    assert!(tl.has_cross_lane_overlap(), "NA lanes must overlap (Fig 5c)");
    assert_eq!(tl.barriers.len(), 1, "exactly one NA→SA barrier");
    let (label, at) = &tl.barriers[0];
    assert!(label.contains("NA"));
    // every SA span starts at/after the barrier
    for spans in tl.lanes.values() {
        for span in spans {
            if span.stage == StageId::SemanticAggregation {
                assert!(
                    span.begin_ns >= *at - 1.0,
                    "SA span at {} before barrier {at}",
                    span.begin_ns
                );
            }
        }
    }
    let rendered = tl.render(80);
    assert!(rendered.contains("barrier"));
}

#[test]
fn mixing_beats_plain_parallel_in_model() {
    // §5 guideline 1 (idealized overlap bound)
    let mut s = session(DatasetId::Imdb);
    s.set_schedule(SchedulePolicy::InterSubgraphParallel { workers: 2 });
    let par = s.run().unwrap();
    s.set_schedule(SchedulePolicy::BoundAwareMixing { workers: 2 });
    let mix = s.run().unwrap();
    assert!(
        mix.report.modeled_makespan_ns <= par.report.modeled_makespan_ns + 1.0,
        "mixing {:.0} vs parallel {:.0}",
        mix.report.modeled_makespan_ns,
        par.report.modeled_makespan_ns
    );
}

#[test]
fn fused_schedule_distributes_fp() {
    // §5 guideline 2: no serial FP phase; projections ride inside NA tasks
    let mut s = session(DatasetId::Imdb);
    s.set_schedule(SchedulePolicy::FusedSubgraph { workers: 2 });
    let fused = s.run().unwrap();
    let fp_kernels = fused
        .profile
        .kernels
        .iter()
        .filter(|k| k.stage == StageId::FeatureProjection)
        .count();
    assert_eq!(fp_kernels, 0, "fused run should attribute projections to NA tasks");
    // and it still contains sgemm work somewhere
    assert!(fused.profile.kernels.iter().any(|k| k.exec.name == "sgemm"));
}

#[test]
fn single_worker_parallel_equals_sequential_makespan() {
    let mut s = session(DatasetId::Acm);
    let seq = s.run().unwrap();
    s.set_schedule(SchedulePolicy::InterSubgraphParallel { workers: 1 });
    let par1 = s.run().unwrap();
    let rel_diff = (seq.report.modeled_makespan_ns - par1.report.modeled_makespan_ns).abs()
        / seq.report.modeled_makespan_ns.max(1.0);
    assert!(rel_diff < 1e-9, "1-worker parallel == sequential, diff {rel_diff}");
}
