//! Coordinator integration: scheduling policies over real models —
//! numerical equivalence, modeled-makespan ordering, timeline shape
//! (Fig 5c) and the §5 guideline ablations.

use hgnn_char::coordinator::{Coordinator, SchedulePolicy};
use hgnn_char::datasets::{self, DatasetId, DatasetScale};
use hgnn_char::engine::Backend;
use hgnn_char::models::{self, ModelConfig};
use hgnn_char::profiler::StageId;

fn setup(
    dataset: DatasetId,
) -> (hgnn_char::graph::HeteroGraph, hgnn_char::models::ModelPlan) {
    let hg = datasets::build(dataset, &DatasetScale::factor(0.25)).unwrap();
    let plan = models::han_plan(&hg, &ModelConfig::default()).unwrap();
    (hg, plan)
}

#[test]
fn policies_numerically_equivalent_at_scale() {
    let (hg, plan) = setup(DatasetId::Dblp);
    let coord = Coordinator::new(Backend::native_no_traces());
    let seq = coord.run(&plan, &hg, SchedulePolicy::Sequential).unwrap();
    for policy in [
        SchedulePolicy::InterSubgraphParallel { workers: 3 },
        SchedulePolicy::FusedSubgraph { workers: 3 },
        SchedulePolicy::BoundAwareMixing { workers: 3 },
    ] {
        let run = coord.run(&plan, &hg, policy).unwrap();
        assert!(
            run.output.allclose(&seq.output, 1e-3, 1e-4),
            "{}: max diff {}",
            policy.label(),
            run.output.max_abs_diff(&seq.output).unwrap()
        );
    }
}

#[test]
fn inter_subgraph_parallelism_improves_makespan() {
    // Fig 5c observation: NA subgraphs are independent => parallel
    // streams shorten the modeled NA phase.
    let (hg, plan) = setup(DatasetId::Dblp);
    let coord = Coordinator::new(Backend::native_no_traces());
    let seq = coord.run(&plan, &hg, SchedulePolicy::Sequential).unwrap();
    let par = coord
        .run(&plan, &hg, SchedulePolicy::InterSubgraphParallel { workers: 3 })
        .unwrap();
    assert!(
        par.report.modeled_makespan_ns < seq.report.modeled_makespan_ns,
        "parallel {:.0} !< sequential {:.0}",
        par.report.modeled_makespan_ns,
        seq.report.modeled_makespan_ns
    );
    assert!(par.report.speedup > 1.0);
}

#[test]
fn timeline_shows_parallel_na_and_barrier() {
    let (hg, plan) = setup(DatasetId::Dblp);
    let coord = Coordinator::new(Backend::native_no_traces());
    let par = coord
        .run(&plan, &hg, SchedulePolicy::InterSubgraphParallel { workers: 3 })
        .unwrap();
    let tl = par.profile.timeline();
    assert!(tl.has_cross_lane_overlap(), "NA lanes must overlap (Fig 5c)");
    assert_eq!(tl.barriers.len(), 1, "exactly one NA→SA barrier");
    let (label, at) = &tl.barriers[0];
    assert!(label.contains("NA"));
    // every SA span starts at/after the barrier
    for spans in tl.lanes.values() {
        for s in spans {
            if s.stage == StageId::SemanticAggregation {
                assert!(
                    s.begin_ns >= *at - 1.0,
                    "SA span at {} before barrier {at}",
                    s.begin_ns
                );
            }
        }
    }
    let rendered = tl.render(80);
    assert!(rendered.contains("barrier"));
}

#[test]
fn mixing_beats_plain_parallel_in_model() {
    // §5 guideline 1 (idealized overlap bound)
    let (hg, plan) = setup(DatasetId::Imdb);
    let coord = Coordinator::new(Backend::native_no_traces());
    let par = coord
        .run(&plan, &hg, SchedulePolicy::InterSubgraphParallel { workers: 2 })
        .unwrap();
    let mix = coord
        .run(&plan, &hg, SchedulePolicy::BoundAwareMixing { workers: 2 })
        .unwrap();
    assert!(
        mix.report.modeled_makespan_ns <= par.report.modeled_makespan_ns + 1.0,
        "mixing {:.0} vs parallel {:.0}",
        mix.report.modeled_makespan_ns,
        par.report.modeled_makespan_ns
    );
}

#[test]
fn fused_schedule_distributes_fp() {
    // §5 guideline 2: no serial FP phase; projections ride inside NA tasks
    let (hg, plan) = setup(DatasetId::Imdb);
    let coord = Coordinator::new(Backend::native_no_traces());
    let fused = coord.run(&plan, &hg, SchedulePolicy::FusedSubgraph { workers: 2 }).unwrap();
    let fp_kernels = fused
        .profile
        .kernels
        .iter()
        .filter(|k| k.stage == StageId::FeatureProjection)
        .count();
    assert_eq!(fp_kernels, 0, "fused run should attribute projections to NA tasks");
    // and it still contains sgemm work somewhere
    assert!(fused.profile.kernels.iter().any(|k| k.exec.name == "sgemm"));
}

#[test]
fn single_worker_parallel_equals_sequential_makespan() {
    let (hg, plan) = setup(DatasetId::Acm);
    let coord = Coordinator::new(Backend::native_no_traces());
    let seq = coord.run(&plan, &hg, SchedulePolicy::Sequential).unwrap();
    let par1 = coord
        .run(&plan, &hg, SchedulePolicy::InterSubgraphParallel { workers: 1 })
        .unwrap();
    let rel_diff = (seq.report.modeled_makespan_ns - par1.report.modeled_makespan_ns).abs()
        / seq.report.modeled_makespan_ns.max(1.0);
    assert!(rel_diff < 1e-9, "1-worker parallel == sequential, diff {rel_diff}");
}
