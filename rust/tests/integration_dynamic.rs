//! Dynamic-graph integration (the ISSUE-7 acceptance criteria): the
//! epoch barrier applies streamed updates atomically, and the patched
//! session is **bit-identical** to a cold session built from the
//! fully-applied graph across models × shards {1,2} × reuse on/off;
//! buffered updates are invisible until the flip (snapshot isolation);
//! the serving barrier drains in-flight waves before flipping while
//! queued requests land on the new epoch (virtual clock, no sleeps);
//! `set_weights` bumps every reuse lane; and a flip after N single-edge
//! updates recomputes NA only for the touched destinations (asserted
//! via the flip profile's kernel attributions, not just the report).

use std::collections::BTreeSet;
use std::sync::{mpsc, Arc, Mutex};
use std::time::Duration;

use hgnn_char::datasets::{self, DatasetId, DatasetScale};
use hgnn_char::dynamic::{DynamicSpec, EpochReport, GraphUpdate};
use hgnn_char::graph::HeteroGraph;
use hgnn_char::models::ModelId;
use hgnn_char::partition::PartitionSpec;
use hgnn_char::profiler::{Profile, StageId};
use hgnn_char::reuse::ReuseSpec;
use hgnn_char::sampler::SamplingSpec;
use hgnn_char::serving::{AsyncServer, BatchExecutor, ServingConfig, SubmitOpts};
use hgnn_char::session::{Session, SessionBuilder};
use hgnn_char::testutil::VirtualClock;
use hgnn_char::Result;

const RECV: Duration = Duration::from_secs(60);

/// Dynamic session over CI-scale IMDB. The reuse arm stacks full-fanout
/// sampling (reuse memoizes sampled-batch stage results); the plain arm
/// serves the cached full-graph forward.
fn dyn_builder(model: ModelId, shards: Option<usize>, reuse: bool) -> SessionBuilder {
    let mut b = Session::builder()
        .dataset(DatasetId::Imdb)
        .scale(DatasetScale::ci())
        .model(model)
        .dynamic(DynamicSpec::default());
    if reuse {
        b = b.sampling(SamplingSpec::uniform(usize::MAX, 1)).reuse(ReuseSpec::rows(1 << 14));
    }
    if let Some(k) = shards {
        b = b.partition(PartitionSpec::new(k));
    }
    b
}

/// Cold oracle: a fresh session over an already-applied graph, same
/// model/sampling/reuse/partition stack, no dynamic machinery.
fn cold_builder(
    hg: HeteroGraph,
    model: ModelId,
    shards: Option<usize>,
    reuse: bool,
) -> SessionBuilder {
    let mut b = Session::builder().graph(hg).model(model);
    if reuse {
        b = b.sampling(SamplingSpec::uniform(usize::MAX, 1)).reuse(ReuseSpec::rows(1 << 14));
    }
    if let Some(k) = shards {
        b = b.partition(PartitionSpec::new(k));
    }
    b
}

/// A churn batch exercising every structural update kind: a genuinely
/// new edge that propagates into the composed metapaths (the director
/// already directs, the movie is new to their row), a feature rewrite,
/// an appended node, and an edge referencing the appended node.
fn churn(hg: &HeteroGraph) -> Vec<GraphUpdate> {
    let md = hg.relations().iter().position(|r| r.name == "M-D").unwrap();
    let dm = hg.relations().iter().position(|r| r.name == "D-M").unwrap();
    let m = hg.type_by_tag('M').unwrap();
    let dim = hg.node_type(m).feat_dim;
    let d = (0..hg.relation(dm).adj.n_rows)
        .find_map(|r| hg.relation(dm).adj.row(r).first().copied())
        .unwrap();
    let row = hg.relation(md).adj.row(d as usize);
    let c = (0..hg.relation(md).adj.n_cols as u32).find(|c| !row.contains(c)).unwrap();
    let new_id = hg.node_type(m).count as u32;
    vec![
        GraphUpdate::AddEdge { relation: md, dst: d, src: c },
        GraphUpdate::SetFeatures { ty: m, node: 0, features: vec![0.25; dim] },
        GraphUpdate::AddNode { ty: m, features: vec![0.75; dim] },
        GraphUpdate::AddEdge { relation: md, dst: d, src: new_id },
    ]
}

// ------------------------------------------------------------ bit-identity

/// The headline acceptance: after a warm run, buffered churn and one
/// flip, the patched-in-place session answers bit-identically to a cold
/// session built from the fully-applied graph — for every model ×
/// shards {1,2} × reuse on/off, including a batch that seeds the node
/// appended by the flip.
#[test]
fn incremental_flip_matches_cold_rebuild_across_the_matrix() {
    let ids: [u32; 6] = [0, 1, 2, 3, 4, 5];
    for model in [ModelId::Rgcn, ModelId::Han, ModelId::Magnn] {
        for shards in [None, Some(2)] {
            for reuse in [false, true] {
                let label = format!("{model:?} shards={shards:?} reuse={reuse}");
                let mut inc = dyn_builder(model, shards, reuse).build().unwrap();
                // warm: materializes the full forward (plain arm) or the
                // reuse caches (sampled arm) so the flip has state to patch
                let _ = inc.run_batch(&ids).unwrap();
                let updates = churn(inc.graph());
                let new_id = inc.graph().node_type(inc.graph().type_by_tag('M').unwrap()).count
                    as u32;
                inc.apply_updates(updates.clone()).unwrap();
                let report = inc.flip_epoch().unwrap();
                assert_eq!(report.epoch, 1, "{label}");
                assert_eq!(report.updates_applied, updates.len(), "{label}");
                assert!(report.rebuilt_subgraphs > 0, "{label}: churn rebuilds sub-CSRs");

                let runs_after_flip = inc.runs();
                let mut cold =
                    cold_builder(inc.graph().clone(), model, shards, reuse).build().unwrap();
                for batch in [&ids[..], &[0, 2, new_id][..]] {
                    let got = inc.run_batch(batch).unwrap();
                    let want = cold.run_batch(batch).unwrap();
                    assert_eq!(
                        got, want,
                        "{label}: post-flip replies must be bit-identical to a \
                         cold rebuild from the applied graph"
                    );
                }
                if !reuse {
                    // plain arm: the flip refreshed the cached forward in
                    // place — serving after it never re-ran the full model
                    assert_eq!(
                        inc.runs(),
                        runs_after_flip,
                        "{label}: patched cache must serve without a fresh full run"
                    );
                }
            }
        }
    }
}

// ------------------------------------------------------ snapshot isolation

/// Buffered updates are invisible: the served snapshot (counts, rows,
/// run counter) is untouched between `apply_updates` and the flip.
#[test]
fn buffered_updates_are_invisible_until_the_flip() {
    let ids: [u32; 4] = [0, 1, 2, 3];
    let mut s = dyn_builder(ModelId::Han, None, false).build().unwrap();
    let before = s.run_batch(&ids).unwrap();
    let snap0 = s.snapshot();
    assert_eq!((snap0.epoch, snap0.pending_updates), (0, 0));

    let updates = churn(s.graph());
    let pending = s.apply_updates(updates.clone()).unwrap();
    assert_eq!(pending, updates.len());

    let snap1 = s.snapshot();
    assert_eq!(snap1.epoch, 0, "no flip yet");
    assert_eq!(snap1.pending_updates, updates.len());
    assert_eq!(snap1.node_counts, snap0.node_counts, "buffered AddNode invisible");
    assert_eq!(snap1.edge_counts, snap0.edge_counts, "buffered AddEdge invisible");
    assert_eq!(s.run_batch(&ids).unwrap(), before, "served rows still the old epoch");
    assert_eq!(s.runs(), 1, "isolation is structural: no recompute happened");

    let report = s.flip_epoch().unwrap();
    assert_eq!(report.updates_applied, updates.len());
    let snap2 = s.snapshot();
    assert_eq!((snap2.epoch, snap2.pending_updates), (1, 0));
    let m = s.graph().type_by_tag('M').unwrap();
    assert_eq!(snap2.node_counts[m], snap0.node_counts[m] + 1, "AddNode landed");
    assert!(
        snap2.edge_counts.iter().sum::<usize>() > snap0.edge_counts.iter().sum::<usize>(),
        "AddEdge landed"
    );
    assert_ne!(s.run_batch(&ids).unwrap(), before, "the flip changed node 0's features");
}

// ------------------------------------------------- serving barrier ordering

/// Epoch-tagged gated executor: every reply row carries the epoch it
/// executed under, `execute` blocks on `gate` (signalling `entered`),
/// and flips are just an epoch bump — isolating the *dispatcher's*
/// barrier ordering from real model execution.
struct EpochEcho {
    epoch: u64,
    pending: usize,
    entered: mpsc::Sender<()>,
    gate: mpsc::Receiver<()>,
    log: Arc<Mutex<Vec<(u64, Vec<u32>)>>>,
}

impl BatchExecutor for EpochEcho {
    fn execute(&mut self, ids: &[u32]) -> Result<Vec<Vec<f32>>> {
        let _ = self.entered.send(());
        let _ = self.gate.recv();
        self.log.lock().unwrap().push((self.epoch, ids.to_vec()));
        Ok(ids.iter().map(|&i| vec![self.epoch as f32, i as f32]).collect())
    }

    fn apply_updates(&mut self, updates: Vec<GraphUpdate>) -> Result<usize> {
        self.pending += updates.len();
        Ok(self.pending)
    }

    fn flip_epoch(&mut self) -> Result<EpochReport> {
        self.epoch += 1;
        let updates_applied = std::mem::take(&mut self.pending);
        Ok(EpochReport {
            epoch: self.epoch,
            updates_applied,
            rebuilt_subgraphs: 0,
            patched_subgraphs: 0,
            na_rows_recomputed: 0,
            evicted_proj: 0,
            evicted_agg: 0,
            shards_patched: 0,
            full_invalidation: false,
            pause_nanos: 0,
            profile: None,
        })
    }

    fn epoch(&self) -> u64 {
        self.epoch
    }
}

/// The barrier runs strictly between waves: the in-flight wave finishes
/// on the old epoch, and a request already *queued* when the flip was
/// requested executes on the new one. Deterministic on the virtual
/// clock — waves close by size, nothing depends on real time.
#[test]
fn flip_drains_inflight_waves_and_requeued_requests_see_the_new_epoch() {
    let clock = Arc::new(VirtualClock::new());
    let log = Arc::new(Mutex::new(Vec::new()));
    let (gate_tx, gate_rx) = mpsc::channel::<()>();
    let (entered_tx, entered_rx) = mpsc::channel::<()>();
    let exec_log = Arc::clone(&log);
    let server = AsyncServer::start_with_clock(
        ServingConfig {
            max_batch: 1,
            flush_after: Duration::from_millis(1),
            priority_lanes: 1,
            ..Default::default()
        },
        clock,
        move || EpochEcho {
            epoch: 0,
            pending: 0,
            entered: entered_tx,
            gate: gate_rx,
            log: exec_log,
        },
    );
    let a = server.submit(&[1], SubmitOpts::default()).unwrap();
    entered_rx.recv_timeout(RECV).unwrap(); // dispatcher blocked inside wave A
    let updates = vec![GraphUpdate::AddEdge { relation: 0, dst: 0, src: 0 }];
    let apply_rx = server.apply_updates(updates).unwrap();
    let flip_rx = server.flip_epoch().unwrap();
    let b = server.submit(&[2], SubmitOpts::default()).unwrap();
    for _ in 0..2 {
        let _ = gate_tx.send(());
    }

    let rows_a = a.recv_timeout(RECV).unwrap().unwrap();
    assert_eq!(rows_a, vec![vec![0.0, 1.0]], "the in-flight wave completed on epoch 0");
    assert_eq!(apply_rx.recv_timeout(RECV).unwrap().unwrap(), 1, "append acked");
    let report = flip_rx.recv_timeout(RECV).unwrap().unwrap();
    assert_eq!((report.epoch, report.updates_applied), (1, 1));
    let rows_b = b.recv_timeout(RECV).unwrap().unwrap();
    assert_eq!(
        rows_b,
        vec![vec![1.0, 2.0]],
        "a request queued before the barrier executes on the new epoch"
    );
    let _ = server.shutdown();
    assert_eq!(
        log.lock().unwrap().as_slice(),
        &[(0, vec![1]), (1, vec![2])],
        "dispatch order: old-epoch wave, barrier, new-epoch wave"
    );
}

/// End-to-end through a real dynamic session behind the dispatcher:
/// pre-flip replies match a cold session over the base graph, the flip
/// report round-trips, and post-flip replies match a cold session over
/// the applied graph.
#[test]
fn served_replies_flip_epochs_bit_identically() {
    let ids: [u32; 3] = [0, 1, 2];
    let base = datasets::build(DatasetId::Imdb, &DatasetScale::ci()).unwrap();
    let updates = churn(&base);

    let server = dyn_builder(ModelId::Han, None, false).serve_async(ServingConfig {
        max_batch: 8,
        flush_after: Duration::from_millis(1),
        priority_lanes: 1,
        ..Default::default()
    });
    // pre-flip: awaited before the controls are queued, so this wave
    // deterministically executes on epoch 0
    let got0 = server
        .submit(&ids, SubmitOpts::default())
        .unwrap()
        .recv_timeout(RECV)
        .unwrap()
        .unwrap();
    let mut old_cold = cold_builder(base.clone(), ModelId::Han, None, false).build().unwrap();
    assert_eq!(got0, old_cold.run_batch(&ids).unwrap(), "epoch-0 replies match cold base");

    let _ = server.apply_updates(updates.clone()).unwrap();
    let report = server
        .flip_epoch()
        .unwrap()
        .recv_timeout(RECV)
        .unwrap()
        .expect("flip succeeds through the dispatcher");
    assert_eq!((report.epoch, report.updates_applied), (1, updates.len()));
    assert!(report.na_rows_recomputed > 0, "the served forward was patched in place");

    // twin session applies the same batch to derive the applied graph
    let mut twin = dyn_builder(ModelId::Han, None, false).build().unwrap();
    twin.apply_updates(updates).unwrap();
    twin.flip_epoch().unwrap();
    let mut new_cold =
        cold_builder(twin.graph().clone(), ModelId::Han, None, false).build().unwrap();
    let got1 = server
        .submit(&ids, SubmitOpts::default())
        .unwrap()
        .recv_timeout(RECV)
        .unwrap()
        .unwrap();
    assert_eq!(got1, new_cold.run_batch(&ids).unwrap(), "epoch-1 replies match cold applied");
    let _ = server.shutdown();
}

// --------------------------------------------------------- reuse lane churn

/// Regression: a weight swap invalidates **every** reuse lane of a
/// sharded session (each lane's generation bumps exactly once), and the
/// aggregate view absorbs all lane bumps — not just lane 0's.
#[test]
fn set_weights_bumps_every_reuse_lane_generation() {
    let mut s = Session::builder()
        .dataset(DatasetId::Imdb)
        .scale(DatasetScale::ci())
        .model(ModelId::Han)
        .sampling(SamplingSpec::uniform(usize::MAX, 1))
        .reuse(ReuseSpec::rows(1 << 14))
        .partition(PartitionSpec::new(2))
        .build()
        .unwrap();
    let _ = s.run_batch(&[0, 1, 2, 3, 4, 5]).unwrap();
    let before = s.reuse_lane_stats().unwrap();
    assert_eq!(before.len(), 2, "one reuse lane per shard");
    assert!(before.iter().all(|l| l.invalidations == 0));

    let w = s.plan().weights.clone();
    s.set_weights(w).unwrap();
    let lanes = s.reuse_lane_stats().unwrap();
    for (i, lane) in lanes.iter().enumerate() {
        assert_eq!(lane.invalidations, 1, "lane {i} must be invalidated by set_weights");
    }
    // the aggregate stats view absorbs every lane's counters
    assert_eq!(s.reuse_stats().unwrap().invalidations, lanes.len() as u64);
}

/// A flip whose batch ends in `SetWeights` degrades to a full
/// invalidation: the report says so and every reuse lane bumps once,
/// while outputs still match a cold session with the same weights.
#[test]
fn flip_with_setweights_reports_full_invalidation() {
    let mut s = dyn_builder(ModelId::Han, Some(2), true).build().unwrap();
    let _ = s.run_batch(&[0, 1, 2, 3]).unwrap();
    let w = Box::new(s.plan().weights.clone());
    s.apply_updates(vec![GraphUpdate::SetWeights(w)]).unwrap();
    let report = s.flip_epoch().unwrap();
    assert!(report.full_invalidation, "SetWeights degrades the flip");
    assert_eq!(report.rebuilt_subgraphs, 0, "no structural churn in the batch");
    let lanes = s.reuse_lane_stats().unwrap();
    assert!(lanes.iter().all(|l| l.invalidations == 1), "every lane bumped");
    let mut cold = cold_builder(s.graph().clone(), ModelId::Han, Some(2), true).build().unwrap();
    assert_eq!(s.run_batch(&[0, 1, 2, 3]).unwrap(), cold.run_batch(&[0, 1, 2, 3]).unwrap());
}

// ------------------------------------------------------- incremental extent

/// Bytes moved by a profile's Neighbor Aggregation kernels.
fn na_bytes(p: &Profile) -> u64 {
    p.kernels
        .iter()
        .filter(|k| k.stage == StageId::NeighborAggregation)
        .map(|k| k.exec.counters.bytes_read + k.exec.counters.bytes_written)
        .sum()
}

/// The kernel-count acceptance: after N single-edge updates into ONE
/// relation, the flip recomputes NA only for the N touched destinations
/// — exactly one subgraph's NA kernels appear in the flip profile, with
/// strictly less NA traffic and fewer NA kernel launches than the full
/// run that preceded it.
#[test]
fn flip_recomputes_na_only_for_touched_destinations() {
    let mut s = dyn_builder(ModelId::Rgcn, None, false).build().unwrap();
    let full = s.run().unwrap();

    // N genuinely-new single edges, one per distinct destination row
    let md = s.graph().relations().iter().position(|r| r.name == "M-D").unwrap();
    let adj = &s.graph().relation(md).adj;
    let n = adj.n_rows.min(3);
    let mut updates = Vec::new();
    for d in 0..n {
        let row = adj.row(d);
        let src = (0..adj.n_cols as u32).find(|c| !row.contains(c)).unwrap();
        updates.push(GraphUpdate::AddEdge { relation: md, dst: d as u32, src });
    }
    s.apply_updates(updates).unwrap();
    let report = s.flip_epoch().unwrap();

    assert_eq!(report.rebuilt_subgraphs, 1, "only the M-D relation subgraph re-derives");
    assert_eq!(report.patched_subgraphs, 1);
    assert_eq!(report.na_rows_recomputed, n, "exactly the N touched destinations");

    let flip = report.profile.expect("a materialized forward was patched");
    let attributed: BTreeSet<&String> = flip
        .kernels
        .iter()
        .filter(|k| k.stage == StageId::NeighborAggregation)
        .filter_map(|k| k.subgraph.as_ref())
        .collect();
    assert_eq!(attributed.len(), 1, "NA kernels launched for one subgraph only");
    let flip_na = flip
        .kernels
        .iter()
        .filter(|k| k.stage == StageId::NeighborAggregation)
        .count();
    let full_na = full
        .profile
        .kernels
        .iter()
        .filter(|k| k.stage == StageId::NeighborAggregation)
        .count();
    assert!(flip_na < full_na, "flip NA kernels {flip_na} < full-run {full_na}");
    assert!(
        na_bytes(&flip) < na_bytes(&full.profile),
        "the compact patch moves less NA data than the full forward"
    );

    // and the incremental result still matches a cold rebuild
    let mut cold = cold_builder(s.graph().clone(), ModelId::Rgcn, None, false).build().unwrap();
    let ids: [u32; 4] = [0, 1, 2, 3];
    assert_eq!(s.run_batch(&ids).unwrap(), cold.run_batch(&ids).unwrap());
}

// ----------------------------------------------------------- error surface

/// A batch with one bad update rejects whole at the barrier — nothing
/// lands, serving continues on the old snapshot, and the next (valid)
/// flip still works.
#[test]
fn invalid_batch_rejects_atomically_and_serving_continues() {
    let ids: [u32; 3] = [0, 1, 2];
    let mut s = dyn_builder(ModelId::Han, None, false).build().unwrap();
    let before = s.run_batch(&ids).unwrap();
    let m = s.graph().type_by_tag('M').unwrap();
    let dim = s.graph().node_type(m).feat_dim;
    let snap0 = s.snapshot();

    // valid AddNode followed by an out-of-bounds edge: the whole batch
    // must reject (no partial application of the AddNode)
    let bogus = vec![
        GraphUpdate::AddNode { ty: m, features: vec![0.5; dim] },
        GraphUpdate::AddEdge { relation: 0, dst: u32::MAX, src: 0 },
    ];
    s.apply_updates(bogus).unwrap();
    assert!(s.flip_epoch().is_err(), "validation rejects the batch at the barrier");
    assert_eq!(s.epoch(), 0, "epoch did not advance");
    let snap1 = s.snapshot();
    assert_eq!(snap1.node_counts, snap0.node_counts, "the AddNode did not land");
    assert_eq!(s.run_batch(&ids).unwrap(), before, "serving continues on the old snapshot");

    // the rejected batch was discarded: a clean batch flips fine
    let updates = churn(s.graph());
    s.apply_updates(updates.clone()).unwrap();
    let report = s.flip_epoch().unwrap();
    assert_eq!(report.updates_applied, updates.len(), "only the clean batch applied");
    assert_eq!(s.epoch(), 1);
}
