//! Cross-model integration: every model on every dataset it supports,
//! checking output sanity, kernel taxonomy coverage and Table 1's stage
//! structure.

use hgnn_char::datasets::{self, DatasetId, DatasetScale};
use hgnn_char::engine::{Backend, Engine};
use hgnn_char::kernels::KernelType;
use hgnn_char::models::{self, ModelConfig, ModelId};
use hgnn_char::profiler::StageId;

fn ci() -> DatasetScale {
    DatasetScale::ci()
}

#[test]
fn full_matrix_runs_and_is_finite() {
    for model in ModelId::HGNNS {
        for dataset in DatasetId::HETERO {
            let hg = datasets::build(dataset, &ci()).unwrap();
            let plan = models::build_plan(model, &hg, &ModelConfig::default()).unwrap();
            let run = Engine::new(Backend::native_no_traces()).run(&plan, &hg).unwrap();
            assert!(
                run.output.as_slice().iter().all(|v| v.is_finite()),
                "{model:?}/{dataset:?} produced non-finite values"
            );
            assert!(run.output.frob_norm() > 0.0, "{model:?}/{dataset:?} all-zero");
        }
    }
}

#[test]
fn table1_stage_operations() {
    // Table 1: RGCN = mean NA + sum SA (no attention kernels);
    // HAN/MAGNN = GAT NA + attention-sum SA.
    let hg = datasets::build(DatasetId::Acm, &ci()).unwrap();
    let cfg = ModelConfig::default();

    let rgcn = models::rgcn_plan(&hg, &cfg).unwrap();
    let run = Engine::new(Backend::native_no_traces()).run(&rgcn, &hg).unwrap();
    let rgcn_names: std::collections::BTreeSet<&str> =
        run.profile.kernels.iter().map(|k| k.exec.name).collect();
    assert!(!rgcn_names.contains("SDDMMCoo"), "RGCN has no attention SDDMM");
    assert!(!rgcn_names.contains("edge_softmax"), "RGCN has no edge softmax");

    let han = models::han_plan(&hg, &cfg).unwrap();
    let run = Engine::new(Backend::native_no_traces()).run(&han, &hg).unwrap();
    let han_names: std::collections::BTreeSet<&str> =
        run.profile.kernels.iter().map(|k| k.exec.name).collect();
    for expected in ["sgemm", "SpMMCsr", "SDDMMCoo", "edge_softmax", "uEleWise", "vEleWise", "Reduce", "Concat"] {
        assert!(han_names.contains(expected), "HAN profile missing {expected}");
    }
}

#[test]
fn all_four_kernel_types_appear_in_han() {
    let hg = datasets::build(DatasetId::Imdb, &ci()).unwrap();
    let plan = models::han_plan(&hg, &ModelConfig::default()).unwrap();
    let run = Engine::new(Backend::native_no_traces()).run(&plan, &hg).unwrap();
    let types: std::collections::BTreeSet<KernelType> =
        run.profile.kernels.iter().map(|k| k.exec.ktype).collect();
    for t in KernelType::ALL {
        assert!(types.contains(&t), "missing kernel type {t:?}");
    }
}

#[test]
fn rgcn_output_independent_of_relation_order_scale() {
    // deterministic weights => two fresh builds agree exactly
    let hg = datasets::build(DatasetId::Dblp, &ci()).unwrap();
    let cfg = ModelConfig::default();
    let a = Engine::new(Backend::native_no_traces())
        .run(&models::rgcn_plan(&hg, &cfg).unwrap(), &hg)
        .unwrap();
    let b = Engine::new(Backend::native_no_traces())
        .run(&models::rgcn_plan(&hg, &cfg).unwrap(), &hg)
        .unwrap();
    assert!(a.output.allclose(&b.output, 0.0, 0.0));
}

#[test]
fn hidden_dim_scales_output_width() {
    let hg = datasets::build(DatasetId::Imdb, &ci()).unwrap();
    for hidden in [16, 32, 128] {
        let cfg = ModelConfig { hidden_dim: hidden, ..ModelConfig::default() };
        let plan = models::han_plan(&hg, &cfg).unwrap();
        let run = Engine::new(Backend::native_no_traces()).run(&plan, &hg).unwrap();
        assert_eq!(run.output.cols(), hidden);
    }
}

#[test]
fn more_metapaths_more_na_kernels() {
    let hg = datasets::build(DatasetId::Dblp, &ci()).unwrap();
    let cfg = ModelConfig::default();
    let count_na = |k: usize| -> usize {
        let paths: Vec<_> = hgnn_char::models::sweeps::DBLP_METAPATH_POOL[..k]
            .iter()
            .map(|s| hgnn_char::metapath::Metapath::parse(s).unwrap())
            .collect();
        let plan = models::han_plan_with(&hg, &cfg, &paths).unwrap();
        let run = Engine::new(Backend::native_no_traces()).run(&plan, &hg).unwrap();
        run.profile
            .kernels
            .iter()
            .filter(|kk| kk.stage == StageId::NeighborAggregation)
            .count()
    };
    let one = count_na(1);
    let three = count_na(3);
    assert_eq!(three, 3 * one, "NA kernel count scales with #metapaths");
}

#[test]
fn gcn_has_no_semantic_stage_work() {
    let hg = datasets::build(DatasetId::RedditSim, &ci()).unwrap();
    let plan = models::gcn_plan(&hg, &ModelConfig::default()).unwrap();
    let run = Engine::new(Backend::native_no_traces()).run(&plan, &hg).unwrap();
    let sa: Vec<_> = run
        .profile
        .kernels
        .iter()
        .filter(|k| k.stage == StageId::SemanticAggregation)
        .collect();
    assert!(sa.is_empty(), "GCN must skip SA, found {} kernels", sa.len());
}
