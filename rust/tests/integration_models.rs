//! Cross-model integration: every model on every dataset it supports,
//! checking output sanity, kernel taxonomy coverage and Table 1's stage
//! structure — all through the `Session` API.

use hgnn_char::datasets::{self, DatasetId, DatasetScale};
use hgnn_char::kernels::KernelType;
use hgnn_char::models::{self, ModelConfig, ModelId, ModelPlan};
use hgnn_char::profiler::StageId;
use hgnn_char::session::{Session, SessionRun};

fn ci() -> DatasetScale {
    DatasetScale::ci()
}

/// One sequential native run of (model, dataset) at CI scale.
fn run_model(model: ModelId, dataset: DatasetId) -> SessionRun {
    Session::builder()
        .dataset(dataset)
        .scale(ci())
        .model(model)
        .build()
        .unwrap()
        .run()
        .unwrap()
}

/// One run over an explicit plan (graph cloned into the session).
fn run_plan(hg: &hgnn_char::graph::HeteroGraph, plan: ModelPlan) -> SessionRun {
    Session::builder()
        .graph(hg.clone())
        .plan(plan)
        .build()
        .unwrap()
        .run()
        .unwrap()
}

#[test]
fn full_matrix_runs_and_is_finite() {
    for model in ModelId::HGNNS {
        for dataset in DatasetId::HETERO {
            let run = run_model(model, dataset);
            assert!(
                run.output.as_slice().iter().all(|v| v.is_finite()),
                "{model:?}/{dataset:?} produced non-finite values"
            );
            assert!(run.output.frob_norm() > 0.0, "{model:?}/{dataset:?} all-zero");
        }
    }
}

#[test]
fn table1_stage_operations() {
    // Table 1: RGCN = mean NA + sum SA (no attention kernels);
    // HAN/MAGNN = GAT NA + attention-sum SA.
    let run = run_model(ModelId::Rgcn, DatasetId::Acm);
    let rgcn_names: std::collections::BTreeSet<&str> =
        run.profile.kernels.iter().map(|k| k.exec.name).collect();
    assert!(!rgcn_names.contains("SDDMMCoo"), "RGCN has no attention SDDMM");
    assert!(!rgcn_names.contains("edge_softmax"), "RGCN has no edge softmax");

    let run = run_model(ModelId::Han, DatasetId::Acm);
    let han_names: std::collections::BTreeSet<&str> =
        run.profile.kernels.iter().map(|k| k.exec.name).collect();
    for expected in ["sgemm", "SpMMCsr", "SDDMMCoo", "edge_softmax", "uEleWise", "vEleWise", "Reduce", "Concat"] {
        assert!(han_names.contains(expected), "HAN profile missing {expected}");
    }
}

#[test]
fn all_four_kernel_types_appear_in_han() {
    let run = run_model(ModelId::Han, DatasetId::Imdb);
    let types: std::collections::BTreeSet<KernelType> =
        run.profile.kernels.iter().map(|k| k.exec.ktype).collect();
    for t in KernelType::ALL {
        assert!(types.contains(&t), "missing kernel type {t:?}");
    }
}

#[test]
fn rgcn_output_independent_of_relation_order_scale() {
    // deterministic weights => two fresh builds agree exactly
    let hg = datasets::build(DatasetId::Dblp, &ci()).unwrap();
    let cfg = ModelConfig::default();
    let a = run_plan(&hg, models::rgcn_plan(&hg, &cfg).unwrap());
    let b = run_plan(&hg, models::rgcn_plan(&hg, &cfg).unwrap());
    assert!(a.output.allclose(&b.output, 0.0, 0.0));
}

#[test]
fn hidden_dim_scales_output_width() {
    for hidden in [16, 32, 128] {
        let cfg = ModelConfig { hidden_dim: hidden, ..ModelConfig::default() };
        let run = Session::builder()
            .dataset(DatasetId::Imdb)
            .scale(ci())
            .model(ModelId::Han)
            .config(cfg)
            .build()
            .unwrap()
            .run()
            .unwrap();
        assert_eq!(run.output.cols(), hidden);
    }
}

#[test]
fn more_metapaths_more_na_kernels() {
    let hg = datasets::build(DatasetId::Dblp, &ci()).unwrap();
    let cfg = ModelConfig::default();
    let count_na = |k: usize| -> usize {
        let paths: Vec<_> = hgnn_char::models::sweeps::DBLP_METAPATH_POOL[..k]
            .iter()
            .map(|s| hgnn_char::metapath::Metapath::parse(s).unwrap())
            .collect();
        let plan = models::han_plan_with(&hg, &cfg, &paths).unwrap();
        let run = run_plan(&hg, plan);
        run.profile
            .kernels
            .iter()
            .filter(|kk| kk.stage == StageId::NeighborAggregation)
            .count()
    };
    let one = count_na(1);
    let three = count_na(3);
    assert_eq!(three, 3 * one, "NA kernel count scales with #metapaths");
}

#[test]
fn gcn_has_no_semantic_stage_work() {
    let run = run_model(ModelId::Gcn, DatasetId::RedditSim);
    let sa: Vec<_> = run
        .profile
        .kernels
        .iter()
        .filter(|k| k.stage == StageId::SemanticAggregation)
        .collect();
    assert!(sa.is_empty(), "GCN must skip SA, found {} kernels", sa.len());
}
