//! Integration tests for the intra-kernel parallel runtime: the worker
//! pool's row-blocked kernels must be **bit-identical** to serial
//! execution across every model, thread count, and composition with
//! reuse caching and sharding — and the session's scratch arena must
//! actually remove steady-state allocations from the serving path.
//!
//! Thread widths are installed via `SessionBuilder::threads`, which
//! scopes the cap thread-locally around each run — so these tests never
//! race each other through a process global.

use hgnn_char::datasets::{DatasetId, DatasetScale};
use hgnn_char::models::ModelId;
use hgnn_char::parallel;
use hgnn_char::reuse::ReuseSpec;
use hgnn_char::sampler::SamplingSpec;
use hgnn_char::session::{PartitionSpec, SchedulePolicy, ServeConfig, Session, SessionBuilder};

fn ci_builder(model: ModelId) -> SessionBuilder {
    Session::builder()
        .dataset(DatasetId::Imdb)
        .scale(DatasetScale::ci())
        .model(model)
}

#[test]
fn forward_bit_identical_across_models_and_threads() {
    for model in [ModelId::Rgcn, ModelId::Han, ModelId::Magnn] {
        let base = ci_builder(model).threads(1).build().unwrap().run().unwrap();
        for t in [2usize, 4] {
            let run = ci_builder(model).threads(t).build().unwrap().run().unwrap();
            assert!(
                run.output.allclose(&base.output, 0.0, 0.0),
                "{model:?} output at {t} threads diverges from serial"
            );
            assert_eq!(run.na_results.len(), base.na_results.len());
            for (i, (a, b)) in run.na_results.iter().zip(&base.na_results).enumerate() {
                assert!(
                    a.allclose(b, 0.0, 0.0),
                    "{model:?} NA result {i} at {t} threads diverges from serial"
                );
            }
        }
    }
}

#[test]
fn parallel_composes_with_worker_schedules() {
    // intra-kernel parallelism under a parallel NA schedule: the pool's
    // nesting rule inlines kernel parallelism inside NA worker tasks,
    // and results stay bit-identical to the serial sequential schedule
    let base = ci_builder(ModelId::Han).threads(1).build().unwrap().run().unwrap();
    let mut s = ci_builder(ModelId::Han)
        .schedule(SchedulePolicy::InterSubgraphParallel { workers: 4 })
        .threads(4)
        .build()
        .unwrap();
    let run = s.run().unwrap();
    assert!(run.output.allclose(&base.output, 0.0, 0.0));
}

#[test]
fn parallel_composes_with_sharding() {
    // nested pool: shard tasks dispatch through the pool, kernels
    // inside them inline — still bit-identical to the monolithic serial
    for model in [ModelId::Rgcn, ModelId::Han, ModelId::Magnn] {
        let base = ci_builder(model).threads(1).build().unwrap().run().unwrap();
        let run = ci_builder(model)
            .threads(4)
            .partition(PartitionSpec::new(2).with_threads(2))
            .build()
            .unwrap()
            .run()
            .unwrap();
        assert!(
            run.output.allclose(&base.output, 0.0, 0.0),
            "{model:?} sharded output at 4 pool threads diverges from serial monolithic"
        );
    }
}

fn sampled_batches(threads: usize, shards: Option<usize>) -> Vec<Vec<Vec<f32>>> {
    let mut builder = ci_builder(ModelId::Han)
        .sampling(SamplingSpec::uniform(usize::MAX, 1))
        .reuse(ReuseSpec::rows(1 << 12))
        .threads(threads);
    if let Some(k) = shards {
        builder = builder.partition(PartitionSpec::new(k).with_threads(k));
    }
    let mut s = builder.build().unwrap();
    let ids = [0u32, 5, 9, 1, 5, 3];
    // two batches: the second hits the reuse caches
    let out = vec![s.run_batch(&ids).unwrap(), s.run_batch(&ids).unwrap()];
    // ...and draws its stage-output buffers from the scratch arena —
    // including the per-shard contexts on a partitioned session
    assert!(s.arena_stats().hits > 0, "warm dispatch must reuse arena buffers");
    out
}

#[test]
fn sampled_reuse_batches_bit_identical_across_threads_and_shards() {
    let base = sampled_batches(1, None);
    assert_eq!(base[0], base[1], "warm cached batch must reproduce the cold batch");
    for t in [2usize, 4] {
        assert_eq!(sampled_batches(t, None), base, "{t} pool threads diverge");
    }
    // composed with --shards 2: shard-affine sub-batches on the pool,
    // one reuse-cache lane per shard
    assert_eq!(sampled_batches(4, Some(2)), base, "sharded batches diverge");
}

#[test]
fn serve_composes_with_threads() {
    let server = ci_builder(ModelId::Han)
        .sampling(SamplingSpec::uniform(8, 1))
        .threads(2)
        .serve(ServeConfig::default());
    let replies: Vec<_> = (0..8u32).map(|i| server.submit(i).unwrap()).collect();
    for rx in replies {
        assert!(rx.recv().is_ok());
    }
    let stats = server.shutdown();
    assert_eq!(stats.completed, 8);
}

#[test]
fn scratch_arena_removes_steady_state_allocations() {
    let mut s = ci_builder(ModelId::Han)
        .sampling(SamplingSpec::uniform(8, 1))
        .threads(1)
        .build()
        .unwrap();
    let ids: Vec<u32> = (0..16).collect();
    let _ = s.run_batch(&ids).unwrap();
    let cold = s.arena_stats();
    let _ = s.run_batch(&ids).unwrap();
    let warm = s.arena_stats();
    assert!(
        warm.hits > cold.hits,
        "second dispatch must draw tensors from the arena: {cold:?} -> {warm:?}"
    );
    // identical dispatches: every checkout the first warm dispatch
    // misses has been parked by then, so misses stop growing entirely
    let _ = s.run_batch(&ids).unwrap();
    let steady = s.arena_stats();
    assert_eq!(
        steady.misses, warm.misses,
        "steady-state dispatches must not allocate fresh tensor buffers"
    );
}

#[test]
fn builder_threads_knob_clamps_and_reports() {
    let s = ci_builder(ModelId::Han).threads(0).build().unwrap();
    assert_eq!(s.threads(), Some(1), "threads(0) clamps to 1");
    let s = ci_builder(ModelId::Han).build().unwrap();
    assert_eq!(s.threads(), None, "default inherits the process pool width");
}

#[test]
fn pool_default_width_is_positive() {
    assert!(parallel::default_threads() >= 1);
    assert!(parallel::current_threads() >= 1);
    assert!(!parallel::in_parallel_region());
}
