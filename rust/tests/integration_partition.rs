//! Integration tests for the sharded execution subsystem: partition
//! structure invariants end-to-end through the session, bit-identity of
//! the sharded forward against the monolithic one, the shard-affine
//! sampled batch path (with and without the per-shard reuse caches),
//! and serving through a sharded session.
//!
//! Bit-identity here means **exact bytes** (`as_slice()` equality, not
//! `allclose`): owner-computes + canonical accumulation order make the
//! sharded forward produce the same f32 stream as the unsharded one, and
//! these tests are the contract that keeps it that way.

use hgnn_char::datasets::{DatasetId, DatasetScale};
use hgnn_char::models::ModelId;
use hgnn_char::partition::PartitionSpec;
use hgnn_char::reuse::ReuseSpec;
use hgnn_char::sampler::SamplingSpec;
use hgnn_char::session::{ServeConfig, Session, SessionBuilder};

fn builder(model: ModelId) -> SessionBuilder {
    Session::builder()
        .dataset(DatasetId::Imdb)
        .scale(DatasetScale::ci())
        .model(model)
}

#[test]
fn sharded_forward_bit_identical_across_models_and_shard_counts() {
    for model in [ModelId::Rgcn, ModelId::Han, ModelId::Magnn] {
        let baseline = builder(model).build().unwrap().run().unwrap();
        for shards in [1usize, 2, 4] {
            let mut session = builder(model)
                .partition(PartitionSpec::new(shards))
                .build()
                .unwrap();
            let run = session.run().unwrap();
            assert_eq!(
                run.output.as_slice(),
                baseline.output.as_slice(),
                "{model:?} at {shards} shards is not bit-identical"
            );
            // the merged per-subgraph NA tensors match too (owner-computes
            // covers every row exactly once)
            assert_eq!(run.na_results.len(), baseline.na_results.len());
            for (a, b) in run.na_results.iter().zip(&baseline.na_results) {
                assert_eq!(a.as_slice(), b.as_slice());
            }
            let report = run.report;
            let info = report.sharding.expect("sharded runs must report sharding");
            assert_eq!(info.shards, shards);
            assert!(report.summary().contains("shards"));
        }
    }
}

#[test]
fn sharded_forward_with_capped_threads_stays_bit_identical() {
    // fewer threads than shards: shards are LPT-packed onto the threads,
    // which must change scheduling only, never results
    let baseline = builder(ModelId::Han).build().unwrap().run().unwrap();
    let mut session = builder(ModelId::Han)
        .partition(PartitionSpec::new(4).with_threads(2))
        .build()
        .unwrap();
    let run = session.run().unwrap();
    assert_eq!(run.output.as_slice(), baseline.output.as_slice());
    assert_eq!(run.report.sharding.unwrap().threads, 2);
}

#[test]
fn sharded_profile_records_halo_and_merge_kernels() {
    let mut session = builder(ModelId::Han)
        .partition(PartitionSpec::new(2))
        .build()
        .unwrap();
    let run = session.run().unwrap();
    let names: Vec<&str> = run.profile.kernels.iter().map(|k| k.exec.name).collect();
    assert!(names.contains(&"HaloExchange"), "missing halo exchange: {names:?}");
    assert!(names.contains(&"ShardMerge"), "missing owner-computes merge: {names:?}");
    // stage percentages still form a closed breakdown
    let pct = run.profile.stage_percentages();
    assert!((pct.values().sum::<f64>() - 100.0).abs() < 1e-6);
}

#[test]
fn builder_rejects_zero_shards() {
    assert!(builder(ModelId::Han).partition(PartitionSpec::new(0)).build().is_err());
    assert!(builder(ModelId::Han)
        .partition(PartitionSpec::new(2).with_threads(0))
        .build()
        .is_err());
}

#[test]
fn partition_accessors_and_owner_lookup() {
    let session = builder(ModelId::Han)
        .partition(PartitionSpec::new(3))
        .build()
        .unwrap();
    let part = session.partition().expect("partitioned session");
    assert_eq!(part.num_shards(), 3);
    let target = session.plan().target;
    let n = session.graph().node_type(target).count as u32;
    for id in 0..n.min(64) {
        let s = session.shard_of(id).unwrap();
        assert!(s < 3);
        assert_eq!(part.owner_of(target, id), s);
        // ids wrap modulo the node count, like run_batch
        assert_eq!(session.shard_of(id + n), Some(s));
    }
    assert!(builder(ModelId::Han).build().unwrap().shard_of(0).is_none());
}

/// R-GCN's semantic aggregation is row-local (sum over relations), so a
/// seed row's sampled-batch embedding is independent of which other
/// seeds share the batch at neighbor-covering fanout — which makes even
/// *mixed* (multi-shard) batches bit-identical between the shard-affine
/// and the monolithic path.
#[test]
fn sharded_batch_path_bit_identical_rgcn_mixed_batch() {
    let ids: Vec<u32> = (0..24).collect();
    let mk = |shards: Option<usize>| {
        let mut b = builder(ModelId::Rgcn).sampling(SamplingSpec::uniform(usize::MAX, 1));
        if let Some(k) = shards {
            b = b.partition(PartitionSpec::new(k));
        }
        b.build().unwrap()
    };
    let plain = mk(None).run_batch(&ids).unwrap();
    for k in [1usize, 2, 4] {
        let sharded = mk(Some(k)).run_batch(&ids).unwrap();
        assert_eq!(plain, sharded, "RGCN mixed batch diverged at {k} shards");
    }
}

#[test]
fn sharded_batch_path_bit_identical_with_reuse_caches() {
    // same comparison with the per-shard reuse caches on: cold batch,
    // then a warm (all-hit) repeat — both must match the unsharded
    // cache-enabled session bit for bit
    let ids: Vec<u32> = (0..24).collect();
    let mk = |shards: Option<usize>| {
        let mut b = builder(ModelId::Rgcn)
            .sampling(SamplingSpec::uniform(usize::MAX, 1))
            .reuse(ReuseSpec::rows(1 << 12));
        if let Some(k) = shards {
            b = b.partition(PartitionSpec::new(k));
        }
        b.build().unwrap()
    };
    let mut plain = mk(None);
    let cold = plain.run_batch(&ids).unwrap();
    let warm = plain.run_batch(&ids).unwrap();
    assert_eq!(cold, warm, "reuse substitution must be bit-identical");
    let mut sharded = mk(Some(2));
    assert_eq!(cold, sharded.run_batch(&ids).unwrap(), "cold sharded batch diverged");
    assert_eq!(cold, sharded.run_batch(&ids).unwrap(), "warm sharded batch diverged");
    let stats = sharded.reuse_stats().unwrap();
    assert!(
        stats.proj_hits > 0 && stats.agg_hits > 0,
        "warm sharded batch must hit the per-shard caches: {stats:?}"
    );
}

/// HAN's semantic attention averages scores over the whole sampled node
/// set, so batch *composition* matters; a shard-pure batch (every seed
/// owned by one shard) executes identically on the shard-affine and the
/// monolithic path — the grouping the serving dispatcher performs.
#[test]
fn sharded_batch_path_bit_identical_han_shard_pure_batch() {
    for reuse in [false, true] {
        let mk = |shards: Option<usize>| {
            let mut b =
                builder(ModelId::Han).sampling(SamplingSpec::uniform(usize::MAX, 1));
            if reuse {
                b = b.reuse(ReuseSpec::rows(1 << 12));
            }
            if let Some(k) = shards {
                b = b.partition(PartitionSpec::new(k));
            }
            b.build().unwrap()
        };
        let mut sharded = mk(Some(2));
        // collect seeds owned by shard 0 — a shard-pure batch
        let n = sharded.graph().node_type(sharded.plan().target).count as u32;
        let pure: Vec<u32> = (0..n).filter(|&i| sharded.shard_of(i) == Some(0)).take(8).collect();
        assert!(!pure.is_empty(), "shard 0 owns no target nodes at ci scale?");
        let mut plain = mk(None);
        let want = plain.run_batch(&pure).unwrap();
        let got = sharded.run_batch(&pure).unwrap();
        assert_eq!(want, got, "HAN shard-pure batch diverged (reuse={reuse})");
        if reuse {
            // repeat: warm per-shard cache must substitute bit-identically
            assert_eq!(want, sharded.run_batch(&pure).unwrap());
        }
    }
}

#[test]
fn sharded_batch_per_shard_results_match_unsharded_subbatches() {
    // a mixed HAN batch splits into shard-affine sub-batches; each seed's
    // row must equal the monolithic execution of its own sub-batch
    let mut sharded = builder(ModelId::Han)
        .sampling(SamplingSpec::uniform(usize::MAX, 1))
        .partition(PartitionSpec::new(2))
        .build()
        .unwrap();
    let ids: Vec<u32> = (0..16).collect();
    let got = sharded.run_batch(&ids).unwrap();
    let mut groups: Vec<Vec<u32>> = vec![Vec::new(); 2];
    for &i in &ids {
        groups[sharded.shard_of(i).unwrap()].push(i);
    }
    let mut plain = builder(ModelId::Han)
        .sampling(SamplingSpec::uniform(usize::MAX, 1))
        .build()
        .unwrap();
    for group in groups.iter().filter(|g| !g.is_empty()) {
        let want = plain.run_batch(group).unwrap();
        for (j, &id) in group.iter().enumerate() {
            assert_eq!(
                want[j],
                got[id as usize],
                "seed {id}: shard-affine row diverged from its sub-batch"
            );
        }
    }
}

#[test]
fn set_weights_refreshes_shard_plans() {
    // rebuild identical weights from the same seed: outputs must stay
    // bit-identical after the swap (stale shard-plan weights would not)
    let mut sharded = builder(ModelId::Rgcn)
        .partition(PartitionSpec::new(2))
        .build()
        .unwrap();
    let before = sharded.run().unwrap();
    let fresh = hgnn_char::models::build_plan(
        ModelId::Rgcn,
        sharded.graph(),
        &hgnn_char::models::ModelConfig::default(),
    )
    .unwrap()
    .weights;
    sharded.set_weights(fresh).unwrap();
    let after = sharded.run().unwrap();
    assert_eq!(before.output.as_slice(), after.output.as_slice());
}

#[test]
fn serve_through_sharded_session() {
    let b = builder(ModelId::Han)
        .sampling(SamplingSpec::uniform(8, 1))
        .reuse(ReuseSpec::rows(1 << 10))
        .partition(PartitionSpec::new(2));
    let server = b.serve(ServeConfig::default());
    let rxs: Vec<_> = (0..24).map(|i| server.submit(i).unwrap()).collect();
    for rx in rxs {
        let row = rx.recv_timeout(std::time::Duration::from_secs(30)).unwrap();
        assert!(!row.is_empty());
        assert!(row.iter().all(|v| v.is_finite()));
    }
    let batch = server.submit_batch(&[3, 1, 2]).unwrap();
    let rows = batch.recv_timeout(std::time::Duration::from_secs(30)).unwrap();
    assert_eq!(rows.len(), 3);
    let stats = server.shutdown();
    assert_eq!(stats.completed, 27);
    assert!(stats.reuse.is_some(), "sharded serving surfaces aggregated reuse stats");
}

#[test]
fn serve_groups_dispatches_by_shard() {
    // a mixed submit_batch through a sharded sampling session must come
    // back in submission order even though execution grouped it by shard
    let b = builder(ModelId::Rgcn)
        .sampling(SamplingSpec::uniform(usize::MAX, 1))
        .partition(PartitionSpec::new(2));
    let server = b.serve(ServeConfig::default());
    let ids: Vec<u32> = (0..12).collect();
    let rx = server.submit_batch(&ids).unwrap();
    let rows = rx.recv_timeout(std::time::Duration::from_secs(30)).unwrap();
    assert_eq!(rows.len(), ids.len());
    // cross-check against a direct session execution of the same ids
    let mut session = builder(ModelId::Rgcn)
        .sampling(SamplingSpec::uniform(usize::MAX, 1))
        .build()
        .unwrap();
    let want = session.run_batch(&ids).unwrap();
    assert_eq!(rows, want, "served rows out of order after shard grouping");
    server.shutdown();
}
