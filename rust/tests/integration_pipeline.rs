//! End-to-end pipeline integration at realistic (quarter-paper) scale:
//! the paper's headline observations must hold structurally — driven
//! through the `Session` API.

use hgnn_char::datasets::{self, DatasetId, DatasetScale};
use hgnn_char::kernels::KernelType;
use hgnn_char::models::{self, ModelConfig, ModelId};
use hgnn_char::profiler::StageId;
use hgnn_char::session::{Profiling, Session, SessionRun};

fn quarter() -> DatasetScale {
    DatasetScale::factor(0.25)
}

fn run_at(
    model: ModelId,
    dataset: DatasetId,
    scale: DatasetScale,
    profiling: Profiling,
) -> SessionRun {
    Session::builder()
        .dataset(dataset)
        .scale(scale)
        .model(model)
        .profiling(profiling)
        .build()
        .unwrap()
        .run()
        .unwrap()
}

#[test]
fn na_dominates_han_dblp_at_scale() {
    // Fig 2's headline: Neighbor Aggregation takes most of HGNN time.
    // HAN on DBLP (the Table 3 configuration) at quarter scale.
    let run = run_at(ModelId::Han, DatasetId::Dblp, quarter(), Profiling::Counters);
    let pct = run.profile.stage_percentages();
    let na = pct[&StageId::NeighborAggregation];
    assert!(
        na > 50.0,
        "NA should dominate HAN-DBLP: FP {:.1} NA {:.1} SA {:.1}",
        pct[&StageId::FeatureProjection],
        na,
        pct[&StageId::SemanticAggregation]
    );
}

#[test]
fn fp_is_dm_dominated_na_is_tb_ew_dominated() {
    // Fig 3's claim: FP is DM-type; NA is TB+EW-type; SA contains DR.
    let run = run_at(ModelId::Han, DatasetId::Dblp, quarter(), Profiling::Counters);
    let ktt = run.profile.kernel_type_times();
    let share = |stage: StageId, t: KernelType| -> f64 {
        let total: f64 = KernelType::ALL
            .iter()
            .map(|&k| ktt.get(&(stage, k)).copied().unwrap_or(0.0))
            .sum();
        100.0 * ktt.get(&(stage, t)).copied().unwrap_or(0.0) / total.max(1e-12)
    };
    assert!(
        share(StageId::FeatureProjection, KernelType::DenseMatmul) > 99.0,
        "FP is pure sgemm"
    );
    let na_tb = share(StageId::NeighborAggregation, KernelType::TopologyBased);
    let na_ew = share(StageId::NeighborAggregation, KernelType::ElementWise);
    assert!(
        na_tb + na_ew > 95.0,
        "NA is TB+EW dominated: TB {na_tb:.1} EW {na_ew:.1}"
    );
    assert!(
        share(StageId::SemanticAggregation, KernelType::DataRearrange) > 1.0,
        "SA contains the Concat DR kernel"
    );
}

#[test]
fn spmm_is_the_na_hotspot_with_low_ai() {
    // Table 3: SpMMCsr dominates NA, with AI well below the ridge.
    let run = run_at(ModelId::Han, DatasetId::Dblp, quarter(), Profiling::Traces);
    let rows = run.profile.kernel_table(StageId::NeighborAggregation);
    let (top_name, top_metrics, top_share) = &rows[0];
    assert_eq!(top_name, "SpMMCsr", "NA hotspot: {rows:?}");
    assert!(*top_share > 50.0, "SpMMCsr share {top_share:.1}%");
    assert!(
        top_metrics.ai < 9.375,
        "SpMM memory-bound (AI {:.2} below ridge)",
        top_metrics.ai
    );
    assert!(top_metrics.peak_perf_pct < 15.0, "SpMM far from peak");
}

#[test]
fn sgemm_compute_bound_on_big_projection() {
    // Fig 4: the FP sgemm sits above the roofline ridge. HAN on IMDB at
    // paper scale projects the dense 3066-dim movie features — a
    // [4278, 3066] x [3066, 64] sgemm that fills the T4.
    let run = run_at(ModelId::Han, DatasetId::Imdb, DatasetScale::paper(), Profiling::Traces);
    let rows = run.profile.kernel_table(StageId::FeatureProjection);
    let (_, m, _) = &rows[0];
    assert!(m.ai > 9.375, "FP sgemm AI {:.1} above ridge", m.ai);
    assert!(m.peak_perf_pct > 50.0, "FP sgemm near peak: {:.1}%", m.peak_perf_pct);
}

#[test]
fn magnn_na_exceeds_han_na() {
    // MAGNN's instance encoding makes NA strictly heavier (paper: MAGNN
    // NA shares are the largest across models).
    let hg = datasets::build(DatasetId::Imdb, &quarter()).unwrap();
    let config = ModelConfig::default();
    let t_han = Session::builder()
        .graph(hg.clone())
        .plan(models::han_plan(&hg, &config).unwrap())
        .build()
        .unwrap()
        .run()
        .unwrap()
        .profile
        .stage_times()[&StageId::NeighborAggregation];
    let t_magnn = Session::builder()
        .graph(hg.clone())
        .plan(models::magnn_plan(&hg, &config).unwrap())
        .build()
        .unwrap()
        .run()
        .unwrap()
        .profile
        .stage_times()[&StageId::NeighborAggregation];
    assert!(t_magnn > t_han, "MAGNN NA {t_magnn} vs HAN NA {t_han}");
}

#[test]
fn sparsity_decreases_with_metapath_length_all_datasets() {
    // Fig 6a across all three HGs at quarter scale.
    for (seed, dataset) in
        [("MAM", DatasetId::Imdb), ("PAP", DatasetId::Acm), ("APA", DatasetId::Dblp)]
    {
        let hg = datasets::build(dataset, &quarter()).unwrap();
        let pts = hgnn_char::metapath::sparsity::sparsity_sweep(&hg, seed, 3).unwrap();
        for w in pts.windows(2) {
            assert!(
                w[1].sparsity <= w[0].sparsity + 1e-12,
                "{dataset:?}: sparsity rose {} -> {}",
                w[0].sparsity,
                w[1].sparsity
            );
        }
        // the §5 correlation model fits well
        if let Some(model) = hgnn_char::metapath::fit_sparsity_model(&pts) {
            assert!(model.r2 > 0.6, "{dataset:?}: weak fit r2={}", model.r2);
            assert!(model.slope >= 0.0);
        }
    }
}

#[test]
fn subgraph_build_excluded_from_gpu_stages() {
    let run = run_at(ModelId::Han, DatasetId::Acm, DatasetScale::ci(), Profiling::Counters);
    assert!(run.profile.subgraph_build_nanos > 0, "SB time recorded");
    assert!(
        run.profile.kernels.iter().all(|k| k.stage != StageId::SubgraphBuild),
        "no GPU kernels attributed to Subgraph Build"
    );
}
