//! Cross-request reuse integration (the ISSUE-3 acceptance criteria):
//! cached `run_batch` outputs are **bit-identical** to cold execution
//! across repeated overlapping batches, tiny capacities evict without
//! corrupting results, weight reloads invalidate by generation, and the
//! serving loop shares one cache across dispatches (chunking oversized
//! requests into `max_batch`-sized sampled dispatches).
//!
//! Bit-identity holds for *all* models — including the
//! semantic-attention ones — because the sampler preserves the node set
//! on cache hits and pins accumulation order via canonical local ids;
//! see `rust/src/reuse/` and `rust/src/sampler/` rustdoc.

use std::time::Duration;

use hgnn_char::datasets::{self, DatasetId, DatasetScale};
use hgnn_char::models::{self, ModelConfig, ModelId};
use hgnn_char::reuse::ReuseSpec;
use hgnn_char::sampler::SamplingSpec;
use hgnn_char::session::{ServeConfig, Session, SessionBuilder};

fn ci_builder(model: ModelId) -> SessionBuilder {
    Session::builder()
        .dataset(DatasetId::Imdb)
        .scale(DatasetScale::ci())
        .model(model)
}

/// Fanout that keeps every neighbor (every row is coverage-exact).
fn full_fanout() -> SamplingSpec {
    SamplingSpec::uniform(usize::MAX, 1)
}

/// The headline acceptance: a reuse session and a cache-less session
/// fed the same overlapping batch sequence produce identical bytes,
/// for row-local (R-GCN) and semantic-attention (HAN) models alike —
/// while the caches demonstrably hit.
#[test]
fn cached_batches_match_cold_execution_bit_identically() {
    for model in [ModelId::Rgcn, ModelId::Han] {
        let mut cold = ci_builder(model).sampling(full_fanout()).build().unwrap();
        let mut warm = ci_builder(model)
            .sampling(full_fanout())
            .reuse(ReuseSpec::rows(1 << 14))
            .build()
            .unwrap();
        let batches: [&[u32]; 5] = [
            &[0, 1, 2, 3, 4, 5, 6, 7],
            &[4, 5, 6, 7, 8, 9, 10, 11], // overlaps the first
            &[0, 1, 2, 3, 4, 5, 6, 7],   // exact repeat
            &[2, 9, 14, 3],              // mixed overlap, new order
            &[20, 21, 0, 9],
        ];
        for ids in batches {
            let a = cold.run_batch(ids).unwrap();
            let b = warm.run_batch(ids).unwrap();
            assert_eq!(a, b, "{model:?}: cached rows must be bit-identical to cold");
        }
        let stats = warm.reuse_stats().unwrap();
        assert!(stats.proj_hits > 0, "{model:?}: projection cache never hit: {stats:?}");
        assert!(stats.agg_hits > 0, "{model:?}: aggregate cache never hit: {stats:?}");
    }
}

/// MAGNN's per-edge instance encoding goes through the same overlay
/// path: hit rows shed their edges, cached rows substitute exactly.
#[test]
fn magnn_reuse_matches_cold_execution() {
    let mut cold = ci_builder(ModelId::Magnn).sampling(full_fanout()).build().unwrap();
    let mut warm = ci_builder(ModelId::Magnn)
        .sampling(full_fanout())
        .reuse(ReuseSpec::rows(1 << 14))
        .build()
        .unwrap();
    for ids in [[0u32, 1, 2, 3], [2, 3, 4, 5], [0, 1, 2, 3]] {
        assert_eq!(cold.run_batch(&ids).unwrap(), warm.run_batch(&ids).unwrap());
    }
    assert!(warm.reuse_stats().unwrap().agg_hits > 0);
}

/// Under a truncating fanout only fully-covered rows (degree ≤ fanout)
/// may consult the aggregate cache, so substitution still reproduces
/// the cache-less outputs exactly; projection reuse applies regardless.
#[test]
fn truncated_fanout_reuse_is_output_preserving() {
    let spec = SamplingSpec::uniform(3, 1);
    let mut cold = ci_builder(ModelId::Han).sampling(spec.clone()).build().unwrap();
    let mut warm = ci_builder(ModelId::Han)
        .sampling(spec)
        .reuse(ReuseSpec::rows(1 << 14))
        .build()
        .unwrap();
    for ids in [[0u32, 1, 2, 3, 4, 5, 6, 7], [2, 3, 4, 5, 6, 7, 8, 9], [0, 1, 2, 3, 4, 5, 6, 7]]
    {
        assert_eq!(cold.run_batch(&ids).unwrap(), warm.run_batch(&ids).unwrap());
    }
    assert!(warm.reuse_stats().unwrap().proj_hits > 0);
}

/// A 4-row cache under 60 distinct seeds churns constantly; eviction
/// must be visible in the counters and invisible in the outputs.
#[test]
fn tiny_capacity_evicts_but_stays_correct() {
    let mut cold = ci_builder(ModelId::Rgcn).sampling(full_fanout()).build().unwrap();
    let mut warm = ci_builder(ModelId::Rgcn)
        .sampling(full_fanout())
        .reuse(ReuseSpec::rows(4))
        .build()
        .unwrap();
    for start in (0..60u32).step_by(6) {
        let ids: Vec<u32> = (start..start + 6).collect();
        assert_eq!(cold.run_batch(&ids).unwrap(), warm.run_batch(&ids).unwrap());
    }
    let stats = warm.reuse_stats().unwrap();
    assert!(stats.evictions > 0, "4-row caches must evict: {stats:?}");
}

/// `Session::set_weights` must clear every cached stage result (the
/// generation bump) and the post-reload batches must match a session
/// built cold with the new weights.
#[test]
fn weight_reload_invalidates_the_caches() {
    let hg = datasets::build(DatasetId::Imdb, &DatasetScale::ci()).unwrap();
    let cfg = ModelConfig::default();
    let plan = models::build_plan(ModelId::Rgcn, &hg, &cfg).unwrap();
    let mut warm = Session::builder()
        .graph(hg)
        .plan(plan)
        .sampling(full_fanout())
        .reuse(ReuseSpec::rows(1 << 14))
        .build()
        .unwrap();
    let ids: Vec<u32> = (0..8).collect();
    let before = warm.run_batch(&ids).unwrap();
    let _ = warm.run_batch(&ids).unwrap();
    assert!(warm.reuse_stats().unwrap().agg_hits > 0, "warm-up must hit");

    // reload weights initialized from a different seed
    let hg2 = datasets::build(DatasetId::Imdb, &DatasetScale::ci()).unwrap();
    let cfg2 = ModelConfig { seed: 0xBEEF, ..ModelConfig::default() };
    let plan2 = models::build_plan(ModelId::Rgcn, &hg2, &cfg2).unwrap();
    warm.set_weights(plan2.weights.clone()).unwrap();
    let stats = warm.reuse_stats().unwrap();
    assert_eq!(stats.invalidations, 1, "set_weights must bump the generation");
    // a shape-incompatible reload is rejected up front
    let wrong = models::build_plan(ModelId::Rgcn, &hg2, &ModelConfig {
        hidden_dim: 16,
        ..ModelConfig::default()
    })
    .unwrap();
    assert!(warm.set_weights(wrong.weights).is_err());

    let after = warm.run_batch(&ids).unwrap();
    assert_ne!(before, after, "new weights must change the embeddings");
    // post-reload rows match a session built cold with the new weights
    let mut cold = Session::builder()
        .graph(hg2)
        .plan(plan2)
        .sampling(full_fanout())
        .build()
        .unwrap();
    assert_eq!(cold.run_batch(&ids).unwrap(), after);
}

/// The serving dispatcher shares one cache across dispatches and
/// surfaces its counters in `ServeStats::reuse`.
#[test]
fn serving_shares_the_cache_across_dispatches() {
    let server = ci_builder(ModelId::Rgcn)
        .sampling(full_fanout())
        .reuse(ReuseSpec::rows(1 << 14))
        .serve(ServeConfig {
            max_batch: 16,
            flush_after: Duration::from_millis(5),
            ..ServeConfig::default()
        });
    let rx1 = server.submit_batch(&[1, 2, 3, 4]).unwrap();
    let rows1 = rx1.recv_timeout(Duration::from_secs(60)).unwrap();
    // second dispatch only after the first completed, so it must go
    // through the (now warm) shared cache
    let rx2 = server.submit_batch(&[1, 2, 3, 4]).unwrap();
    let rows2 = rx2.recv_timeout(Duration::from_secs(60)).unwrap();
    assert_eq!(rows1, rows2, "same ids across dispatches must agree bit-for-bit");
    let stats = server.shutdown();
    let reuse = stats.reuse.expect("session executor must surface reuse stats");
    assert!(reuse.proj_hits > 0, "second dispatch must reuse the first's rows: {reuse:?}");
    assert_eq!(stats.completed, 8);
}

/// `FusedSubgraph` under reuse executes (and must report) the
/// inter-subgraph-parallel shape — fusing FP into NA tasks is
/// incompatible with a shared projection cache — and the report carries
/// the cache counters.
#[test]
fn fused_policy_under_reuse_reports_effective_policy() {
    use hgnn_char::gpumodel::GpuModel;
    use hgnn_char::reuse::ReuseCache;
    use hgnn_char::sampler::NeighborSampler;
    use hgnn_char::session::{exec, ExecBackend, NativeBackend, SchedulePolicy};

    let hg = datasets::build(DatasetId::Imdb, &DatasetScale::ci()).unwrap();
    let plan = models::build_plan(ModelId::Han, &hg, &ModelConfig::default()).unwrap();
    let sampler = NeighborSampler::new(full_fanout()).unwrap();
    let mut cache = ReuseCache::new(ReuseSpec::rows(1 << 12));
    let sampled = sampler.sample_with_cache(&hg, &plan, &[0, 1, 2, 3], &mut cache).unwrap();
    let backend = NativeBackend::new();
    let mut ctx = backend.make_ctx();
    let run = exec::execute_reuse(
        &backend,
        &GpuModel::default(),
        &sampled,
        SchedulePolicy::FusedSubgraph { workers: 2 },
        &mut ctx,
        &mut cache,
    )
    .unwrap();
    assert_eq!(
        run.report.policy,
        SchedulePolicy::InterSubgraphParallel { workers: 2 },
        "the report must name the policy that actually executed"
    );
    assert!(run.report.reuse.is_some());
    assert!(run.profile.reuse.is_some());
}

/// An oversized typed batch is chunked into `max_batch`-sized sampled
/// dispatches whose rows are reassembled in submission order — and for
/// a row-local model those rows equal a single direct dispatch exactly.
#[test]
fn oversized_requests_chunk_into_sampled_dispatches() {
    let server = ci_builder(ModelId::Rgcn)
        .sampling(full_fanout())
        .serve(ServeConfig {
            max_batch: 8,
            flush_after: Duration::from_millis(1),
            ..ServeConfig::default()
        });
    let ids: Vec<u32> = (0..20).collect();
    let rx = server.submit_batch(&ids).unwrap();
    let rows = rx.recv_timeout(Duration::from_secs(60)).unwrap();
    assert_eq!(rows.len(), 20);
    let stats = server.shutdown();
    assert_eq!(stats.batches, 3, "20 ids at max_batch 8 -> 3 sampled dispatches");
    // chunking must not change any row
    let mut session = ci_builder(ModelId::Rgcn).sampling(full_fanout()).build().unwrap();
    assert_eq!(rows, session.run_batch(&ids).unwrap());
}
