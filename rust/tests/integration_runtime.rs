//! PJRT runtime integration: load the AOT JAX/Pallas artifacts, execute
//! them with real graph tensors, and assert numeric agreement with the
//! native Rust engine.
//!
//! Requires `make artifacts` (skipped with a clear message otherwise —
//! CI runs `make test`, which builds artifacts first).

use hgnn_char::datasets::{self, DatasetId, DatasetScale};
use hgnn_char::graph::Csr;
use hgnn_char::metapath::{Metapath, Subgraph, SubgraphSet};
use hgnn_char::models::{self, ModelConfig, ModelId, ModelPlan, ModelWeights};
use hgnn_char::runtime::{ell_inputs, PjrtRuntime};
use hgnn_char::session::Session;
use hgnn_char::tensor::Tensor;

const ELL_K: usize = 64;

fn runtime() -> Option<PjrtRuntime> {
    if cfg!(not(feature = "pjrt")) {
        eprintln!("SKIP: built without the 'pjrt' feature");
        return None;
    }
    let root = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !root.join("manifest.json").exists() {
        eprintln!("SKIP: no artifacts/manifest.json — run `make artifacts`");
        return None;
    }
    Some(PjrtRuntime::new(root).expect("PJRT client"))
}

/// ELL arrays (idx, mask) as f32 tensors for a CSR, truncated at K.
fn ell_tensors(adj: &Csr, k: usize) -> (Tensor, Tensor, Csr) {
    ell_inputs(adj, k)
}

/// Native sequential run of an explicit plan through a session.
fn native_run(hg: &hgnn_char::graph::HeteroGraph, plan: &ModelPlan) -> hgnn_char::session::SessionRun {
    Session::builder()
        .graph(hg.clone())
        .plan(plan.clone())
        .build()
        .unwrap()
        .run()
        .unwrap()
}

fn vec_tensor(rows: usize, cols: usize, v: &[f32]) -> Tensor {
    Tensor::from_vec(rows, cols, v.to_vec()).unwrap()
}

#[test]
fn kernel_dense_matmul_artifact_matches_native() {
    let Some(rt) = runtime() else { return };
    let art = rt.compile_by_name("kernel_dense_matmul").expect("compile");
    let mut rng = hgnn_char::util::Pcg32::seeded(99);
    let a = Tensor::randn(128, 256, 1.0, &mut rng);
    let b = Tensor::randn(256, 64, 1.0, &mut rng);
    let out = art.execute(&[&a, &b]).expect("execute");
    assert_eq!(out.len(), 1);
    let native = hgnn_char::kernels::dense::sgemm_naive(&a, &b);
    assert!(
        out[0].allclose(&native, 1e-3, 1e-3),
        "pallas matmul vs native: max diff {}",
        out[0].max_abs_diff(&native).unwrap()
    );
}

#[test]
fn kernel_ell_spmm_artifact_matches_native() {
    let Some(rt) = runtime() else { return };
    let art = rt.compile_by_name("kernel_ell_spmm").expect("compile");
    let (n, k, f) = (256usize, 16usize, 64usize);
    let mut rng = hgnn_char::util::Pcg32::seeded(7);
    let gathered = Tensor::randn(n * k, f, 1.0, &mut rng);
    let weights = Tensor::randn(n, k, 1.0, &mut rng);
    let mut mask = Tensor::zeros(n, k);
    for r in 0..n {
        for j in 0..k {
            mask.set(r, j, if rng.gen_f32() < 0.6 { 1.0 } else { 0.0 });
        }
    }
    let out = art.execute(&[&gathered, &weights, &mask]).expect("execute");
    // native oracle: masked weighted sum over the K axis
    let mut expect = Tensor::zeros(n, f);
    for r in 0..n {
        for j in 0..k {
            let w = weights.get(r, j) * mask.get(r, j);
            if w != 0.0 {
                let src = gathered.row(r * k + j);
                for (o, &v) in expect.row_mut(r).iter_mut().zip(src) {
                    *o += w * v;
                }
            }
        }
    }
    assert!(
        out[0].allclose(&expect, 1e-4, 1e-4),
        "ell_spmm vs oracle: {}",
        out[0].max_abs_diff(&expect).unwrap()
    );
}

/// Build the HAN-IMDB CI plan whose adjacency is ELL-truncated exactly
/// like the artifact inputs, so native and PJRT compute the same math.
fn han_imdb_truncated_plan(
) -> (hgnn_char::graph::HeteroGraph, ModelPlan, Vec<(Tensor, Tensor)>) {
    let hg = datasets::build(DatasetId::Imdb, &DatasetScale::ci()).unwrap();
    let config = ModelConfig::default();
    let base = models::han_plan(&hg, &config).unwrap();
    let mut ells = Vec::new();
    let mut subgraphs = Vec::new();
    for sg in &base.subgraphs.subgraphs {
        let (idx, mask, trunc) = ell_tensors(&sg.adj, ELL_K);
        ells.push((idx, mask));
        subgraphs.push(Subgraph {
            metapath: Some(Metapath::parse(&sg.name).unwrap()),
            name: sg.name.clone(),
            dst_type: sg.dst_type,
            src_type: sg.src_type,
            adj: trunc,
        });
    }
    let subgraphs = SubgraphSet { subgraphs, build_nanos: 0 };
    let weights = ModelWeights::init(ModelId::Han, &hg, &subgraphs, &config);
    let plan = ModelPlan {
        model: ModelId::Han,
        config,
        subgraphs,
        weights,
        target: base.target,
    };
    (hg, plan, ells)
}

#[test]
fn han_full_model_artifact_matches_native_engine() {
    let Some(rt) = runtime() else { return };
    let art = rt.compile_by_name("han_imdb_ci_full").expect("compile");
    let (hg, plan, ells) = han_imdb_truncated_plan();

    // native execution on the identical (truncated) adjacency
    let native = native_run(&hg, &plan);

    // PJRT execution with the same weights + ELL tensors
    let m_ty = hg.type_by_tag('M').unwrap();
    let x = hg.features(m_ty);
    let w = &plan.weights.proj[&m_ty];
    let h = plan.config.hidden_dim;
    let s = plan.config.semantic_dim;
    let al0 = vec_tensor(1, h, &plan.weights.attn_l[0]);
    let ar0 = vec_tensor(1, h, &plan.weights.attn_r[0]);
    let al1 = vec_tensor(1, h, &plan.weights.attn_l[1]);
    let ar1 = vec_tensor(1, h, &plan.weights.attn_r[1]);
    let sem_w = plan.weights.sem_w.as_ref().unwrap();
    let sem_b = vec_tensor(1, s, &plan.weights.sem_b);
    let sem_q = plan.weights.sem_q.as_ref().unwrap();
    let out = art
        .execute(&[
            x, w, &ells[0].0, &ells[0].1, &ells[1].0, &ells[1].1, &al0, &ar0, &al1, &ar1,
            sem_w, &sem_b, sem_q,
        ])
        .expect("execute HAN artifact");

    assert_eq!(out[0].shape(), native.output.shape());
    assert!(
        out[0].allclose(&native.output, 1e-3, 1e-3),
        "PJRT vs native HAN output: max diff {}",
        out[0].max_abs_diff(&native.output).unwrap()
    );
}

#[test]
fn gcn_artifact_matches_native_engine() {
    let Some(rt) = runtime() else { return };
    let art = rt.compile_by_name("gcn_reddit_ci_full").expect("compile");
    let hg = datasets::build(DatasetId::RedditSim, &DatasetScale::ci()).unwrap();
    let config = ModelConfig::default();
    let base = models::gcn_plan(&hg, &config).unwrap();
    let (idx, mask, trunc) = ell_tensors(&base.subgraphs.subgraphs[0].adj, ELL_K);
    // native on truncated adjacency
    let subgraphs = SubgraphSet {
        subgraphs: vec![Subgraph {
            metapath: None,
            name: "U-U".into(),
            dst_type: 0,
            src_type: 0,
            adj: trunc,
        }],
        build_nanos: 0,
    };
    let weights = ModelWeights::init(ModelId::Gcn, &hg, &subgraphs, &config);
    let plan = ModelPlan { model: ModelId::Gcn, config, subgraphs, weights, target: 0 };
    let native = native_run(&hg, &plan);

    let x = hg.features(0);
    let w = &plan.weights.proj[&0];
    let out = art.execute(&[x, w, &idx, &mask]).expect("execute GCN artifact");
    assert!(
        out[0].allclose(&native.output, 1e-3, 1e-3),
        "PJRT vs native GCN: max diff {}",
        out[0].max_abs_diff(&native.output).unwrap()
    );
}

#[test]
fn artifact_input_shape_validation() {
    let Some(rt) = runtime() else { return };
    let art = rt.compile_by_name("kernel_dense_matmul").expect("compile");
    let wrong = Tensor::zeros(2, 2);
    assert!(art.execute(&[&wrong, &wrong]).is_err(), "shape mismatch must error");
    let a = Tensor::zeros(128, 256);
    assert!(art.execute(&[&a]).is_err(), "arity mismatch must error");
}

#[test]
fn manifest_covers_expected_artifacts() {
    let Some(rt) = runtime() else { return };
    let manifest = rt.manifest().unwrap();
    for name in [
        "han_imdb_ci_full",
        "gcn_reddit_ci_full",
        "kernel_dense_matmul",
        "kernel_ell_spmm",
    ] {
        assert!(manifest.find(name).is_some(), "missing artifact {name}");
    }
}
