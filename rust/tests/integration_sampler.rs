//! Mini-batch sampling integration (the ISSUE-2 acceptance criteria):
//! sampled `run_batch` execution matches full-graph execution when the
//! fanout covers every neighbor, stays deterministic under truncation,
//! and drives the serving loop end-to-end.

use std::time::Duration;

use hgnn_char::datasets::{DatasetId, DatasetScale};
use hgnn_char::models::ModelId;
use hgnn_char::sampler::SamplingSpec;
use hgnn_char::session::{SchedulePolicy, ServeConfig, Session, SessionBuilder};

fn ci_builder(model: ModelId) -> SessionBuilder {
    Session::builder()
        .dataset(DatasetId::Imdb)
        .scale(DatasetScale::ci())
        .model(model)
}

/// Fanout that keeps every neighbor.
fn full_fanout(layers: usize) -> SamplingSpec {
    SamplingSpec::uniform(usize::MAX, layers)
}

fn close(a: &[f32], b: &[f32], tol: f32) -> bool {
    a.len() == b.len()
        && a.iter().zip(b).all(|(x, y)| (x - y).abs() <= tol * (1.0 + y.abs()))
}

/// With the full node set as seeds and full fanout, the sampled pipeline
/// reconstructs the parent graph exactly (identity remap), so even the
/// semantic-attention models agree with the full-graph forward.
#[test]
fn han_sampled_full_coverage_matches_full_graph() {
    let mut baseline = ci_builder(ModelId::Han).build().unwrap();
    let full = baseline.run().unwrap();
    let n = full.output.rows() as u32;
    let ids: Vec<u32> = (0..n).collect();
    let mut sampled = ci_builder(ModelId::Han).sampling(full_fanout(1)).build().unwrap();
    let rows = sampled.run_batch(&ids).unwrap();
    assert_eq!(rows.len(), n as usize);
    for (i, row) in rows.iter().enumerate() {
        assert!(
            close(row, full.output.row(i), 1e-5),
            "node {i}: sampled row diverges from full-graph forward"
        );
    }
}

/// R-GCN's stages are row-local end to end (mean NA, sum SA, no global
/// attention), so a *strict subset* of seeds with neighbor-covering
/// fanout must reproduce the full-graph rows.
#[test]
fn rgcn_sampled_subset_matches_full_graph_rows() {
    let mut baseline = ci_builder(ModelId::Rgcn).build().unwrap();
    let full = baseline.run().unwrap();
    let seeds: Vec<u32> = vec![3, 0, 11, 7, 42];
    let mut sampled = ci_builder(ModelId::Rgcn).sampling(full_fanout(1)).build().unwrap();
    let rows = sampled.run_batch(&seeds).unwrap();
    for (row, &s) in rows.iter().zip(&seeds) {
        assert!(
            close(row, full.output.row(s as usize), 1e-4),
            "seed {s}: sampled row diverges from full-graph forward"
        );
    }
}

/// Sampled equivalence holds under parallel schedule policies too — the
/// sampled (graph, plan) pair flows through the same executor.
#[test]
fn sampled_execution_respects_schedule_policies() {
    let seeds: Vec<u32> = (0..8).collect();
    let mut seq = ci_builder(ModelId::Rgcn).sampling(full_fanout(1)).build().unwrap();
    let base = seq.run_batch(&seeds).unwrap();
    let mut par = ci_builder(ModelId::Rgcn)
        .sampling(full_fanout(1))
        .schedule(SchedulePolicy::InterSubgraphParallel { workers: 2 })
        .build()
        .unwrap();
    let rows = par.run_batch(&seeds).unwrap();
    for (a, b) in rows.iter().zip(&base) {
        assert!(close(a, b, 1e-4), "parallel sampled run diverges from sequential");
    }
}

/// Truncating fanouts change the numbers but stay deterministic, finite
/// and correctly shaped; node ids wrap modulo the target count.
#[test]
fn truncated_fanout_is_deterministic_and_finite() {
    let mut a = ci_builder(ModelId::Han).sampling(SamplingSpec::uniform(2, 1)).build().unwrap();
    let mut b = ci_builder(ModelId::Han).sampling(SamplingSpec::uniform(2, 1)).build().unwrap();
    let n = a.graph().node_type(a.plan().target).count as u32;
    let ids = vec![0, 5, n + 5, 9];
    let ra = a.run_batch(&ids).unwrap();
    let rb = b.run_batch(&ids).unwrap();
    assert_eq!(ra, rb, "same spec + seeds must sample identically");
    assert_eq!(ra[2], ra[1], "ids wrap modulo the target node count");
    for row in &ra {
        assert_eq!(row.len(), a.plan().config.hidden_dim);
        assert!(row.iter().all(|v| v.is_finite()));
    }
    // deeper sampling executes too (frontier expansion)
    let mut deep =
        ci_builder(ModelId::Han).sampling(SamplingSpec::uniform(4, 2)).build().unwrap();
    let rows = deep.run_batch(&[1, 2, 3]).unwrap();
    assert!(rows.iter().all(|r| r.iter().all(|v| v.is_finite())));
}

/// MAGNN's instance-encoding NA runs on sampled subgraphs as well.
#[test]
fn magnn_sampled_batch_executes() {
    let mut s = ci_builder(ModelId::Magnn).sampling(SamplingSpec::uniform(8, 1)).build().unwrap();
    let rows = s.run_batch(&[0, 1, 2, 3]).unwrap();
    assert_eq!(rows.len(), 4);
    assert!(rows.iter().all(|r| r.iter().all(|v| v.is_finite())));
    assert!(rows.iter().any(|r| r.iter().any(|v| *v != 0.0)));
}

/// The serving loop batches queued requests into one sampled subgraph
/// per dispatch and replies to singles and typed batches alike. R-GCN's
/// row-local stages make a node's embedding independent of which other
/// requests share its dispatch, so the same id agrees across request
/// kinds regardless of how the dispatcher grouped them.
#[test]
fn serving_loop_runs_on_sampled_subgraphs() {
    let server = ci_builder(ModelId::Rgcn)
        .sampling(full_fanout(1))
        .serve(ServeConfig {
            max_batch: 32,
            flush_after: Duration::from_millis(20),
            ..ServeConfig::default()
        });
    let single = server.submit(3).unwrap();
    let batch = server.submit_batch(&[4, 5, 6, 3]).unwrap();
    let row = single.recv_timeout(Duration::from_secs(60)).unwrap();
    assert!(!row.is_empty() && row.iter().all(|v| v.is_finite()));
    let rows = batch.recv_timeout(Duration::from_secs(60)).unwrap();
    assert_eq!(rows.len(), 4);
    assert!(
        close(&rows[3], &row, 1e-4),
        "same id must agree across single and typed-batch requests"
    );
    let stats = server.shutdown();
    assert_eq!(stats.completed, 5);
}
