//! Async serving runtime integration (the ISSUE-6 acceptance criteria):
//! wave formation closes on size or timeout, deadline expiry and token
//! refill are exercised deterministically on the virtual clock (no real
//! sleeps decide an outcome), scheduling is earliest-deadline-first
//! within a class without starving large batches, over-capacity load is
//! shed with typed errors while the queue stays bounded, and async
//! replies are bit-identical to the synchronous `run_batch` path across
//! models × shards × reuse.

use std::sync::{mpsc, Arc, Mutex};
use std::time::Duration;

use hgnn_char::datasets::{DatasetId, DatasetScale};
use hgnn_char::models::ModelId;
use hgnn_char::partition::PartitionSpec;
use hgnn_char::reuse::ReuseSpec;
use hgnn_char::sampler::SamplingSpec;
use hgnn_char::serving::{AsyncServer, ServeError, ServingConfig, SubmitOpts};
use hgnn_char::session::{Session, SessionBuilder};
use hgnn_char::testutil::VirtualClock;
use hgnn_char::Result;

const RECV: Duration = Duration::from_secs(60);

fn echo(ids: &[u32]) -> Result<Vec<Vec<f32>>> {
    Ok(ids.iter().map(|&i| vec![i as f32, i as f32 + 0.5]).collect())
}

fn cfg() -> ServingConfig {
    ServingConfig {
        max_batch: 4,
        flush_after: Duration::from_millis(2),
        priority_lanes: 1,
        ..Default::default()
    }
}

/// A gated executor: blocks inside `execute` until the test sends on
/// `gate`, signalling entry on `entered` and appending every dispatched
/// chunk to `log`. Holding the gate freezes the dispatcher so the test
/// can shape the queue, then observe the exact dispatch order.
fn gated(
    log: Arc<Mutex<Vec<Vec<u32>>>>,
) -> (impl FnMut(&[u32]) -> Result<Vec<Vec<f32>>>, mpsc::Sender<()>, mpsc::Receiver<()>) {
    let (gate_tx, gate_rx) = mpsc::channel::<()>();
    let (entered_tx, entered_rx) = mpsc::channel::<()>();
    let exec = move |ids: &[u32]| -> Result<Vec<Vec<f32>>> {
        let _ = entered_tx.send(());
        let _ = gate_rx.recv();
        log.lock().unwrap().push(ids.to_vec());
        echo(ids)
    };
    (exec, gate_tx, entered_rx)
}

// ---------------------------------------------------------------- waves

/// With the clock frozen, a wave can only close by size: `max_batch`
/// singles form exactly one dispatch, no timeout involved.
#[test]
fn wave_closes_on_size_with_frozen_clock() {
    let clock = Arc::new(VirtualClock::new());
    let server = AsyncServer::start_with_clock(cfg(), clock, || echo);
    let rxs: Vec<_> =
        (0..4).map(|i| server.submit(&[i], SubmitOpts::default()).unwrap()).collect();
    for (i, rx) in rxs.into_iter().enumerate() {
        let rows = rx.recv_timeout(RECV).unwrap().unwrap();
        assert_eq!(rows, vec![vec![i as f32, i as f32 + 0.5]]);
    }
    let stats = server.shutdown();
    assert_eq!(stats.batches, 1, "4 singles at max_batch 4 close one wave by size");
    assert_eq!(stats.completed, 4);
}

/// A partial wave closes only when virtual time reaches the fill
/// deadline: one `advance(flush_after)` flushes it, no real sleeping.
#[test]
fn wave_closes_on_timeout_when_virtual_time_advances() {
    let clock = Arc::new(VirtualClock::new());
    let server = AsyncServer::start_with_clock(cfg(), Arc::clone(&clock), || echo);
    let a = server.submit(&[7], SubmitOpts::default()).unwrap();
    let b = server.submit(&[8], SubmitOpts::default()).unwrap();
    // two of four budget ids queued: the wave is held open until the
    // fill window (anchored at the first submit) passes
    clock.advance(Duration::from_millis(2));
    assert!(a.recv_timeout(RECV).unwrap().is_ok());
    assert!(b.recv_timeout(RECV).unwrap().is_ok());
    let stats = server.shutdown();
    assert_eq!(stats.batches, 1, "both singles ride the same timed-out wave");
    assert_eq!(stats.completed, 2);
}

// ------------------------------------------------------------- deadlines

/// A queued request whose deadline passes (in virtual time) while the
/// executor is busy fails fast with `DeadlineExceeded` instead of
/// occupying a dispatch.
#[test]
fn queued_request_expires_at_its_virtual_deadline() {
    let clock = Arc::new(VirtualClock::new());
    let log = Arc::new(Mutex::new(Vec::new()));
    let (exec, gate, entered) = gated(Arc::clone(&log));
    let server = AsyncServer::start_with_clock(
        ServingConfig { max_batch: 1, ..cfg() },
        Arc::clone(&clock),
        move || exec,
    );
    let a = server.submit(&[1], SubmitOpts::default()).unwrap();
    entered.recv_timeout(RECV).unwrap(); // dispatcher now blocked on [1]
    let b = server
        .submit(&[2], SubmitOpts::default().with_deadline(Duration::from_millis(10)))
        .unwrap();
    clock.advance(Duration::from_millis(20));
    for _ in 0..2 {
        let _ = gate.send(());
    }
    assert!(a.recv_timeout(RECV).unwrap().is_ok());
    match b.recv_timeout(RECV).unwrap() {
        Err(ServeError::DeadlineExceeded { late_ns }) => {
            assert_eq!(late_ns, 10_000_000, "expired exactly 10ms late in virtual time")
        }
        other => panic!("expected DeadlineExceeded, got {other:?}"),
    }
    let stats = server.shutdown();
    assert_eq!(stats.expired, 1);
    assert_eq!(log.lock().unwrap().as_slice(), &[vec![1]], "the expired id never dispatched");
}

// ------------------------------------------------------------- admission

/// The token bucket rejects over-rate submissions with a retry hint and
/// refills purely from virtual time.
#[test]
fn token_bucket_refills_on_virtual_time() {
    let clock = Arc::new(VirtualClock::new());
    let config = ServingConfig {
        admission_qps: Some(1000.0), // 1 id per virtual millisecond
        admission_burst: Some(2.0),
        ..cfg()
    };
    let server = AsyncServer::start_with_clock(config, Arc::clone(&clock), || echo);
    let mut rxs = vec![
        server.submit(&[0], SubmitOpts::default()).unwrap(),
        server.submit(&[1], SubmitOpts::default()).unwrap(),
    ];
    match server.submit(&[2], SubmitOpts::default()) {
        Err(ServeError::Overloaded { retry_after_ns }) => {
            assert!(retry_after_ns > 0, "reject must carry a backoff hint");
            assert!(retry_after_ns <= 1_000_000, "one token arrives within 1ms");
        }
        other => panic!("expected Overloaded, got {:?}", other.err()),
    }
    clock.advance(Duration::from_millis(1)); // exactly one token back
    rxs.push(server.submit(&[3], SubmitOpts::default()).unwrap());
    clock.advance(Duration::from_millis(2)); // flush the partial wave
    for rx in rxs {
        assert!(rx.recv_timeout(RECV).unwrap().is_ok());
    }
    let stats = server.shutdown();
    assert_eq!(stats.rejected_overloaded, 1);
    assert_eq!(stats.completed, 3);
}

// ------------------------------------------------------------ scheduling

/// Within a class, dispatch is earliest-deadline-first: a tighter
/// deadline submitted later overtakes an earlier, looser one.
#[test]
fn earliest_deadline_overtakes_within_a_class() {
    let clock = Arc::new(VirtualClock::new());
    let log = Arc::new(Mutex::new(Vec::new()));
    let (exec, gate, entered) = gated(Arc::clone(&log));
    let server = AsyncServer::start_with_clock(
        ServingConfig { max_batch: 1, ..cfg() },
        clock,
        move || exec,
    );
    let g = server.submit(&[99], SubmitOpts::default()).unwrap();
    entered.recv_timeout(RECV).unwrap(); // queue shaping happens while blocked
    let loose = server
        .submit(&[1], SubmitOpts::default().with_deadline(Duration::from_millis(100)))
        .unwrap();
    let tight = server
        .submit(&[2], SubmitOpts::default().with_deadline(Duration::from_millis(10)))
        .unwrap();
    for _ in 0..3 {
        let _ = gate.send(());
    }
    for rx in [g, tight, loose] {
        assert!(rx.recv_timeout(RECV).unwrap().is_ok());
    }
    let stats = server.shutdown();
    assert_eq!(stats.completed, 3);
    assert_eq!(
        log.lock().unwrap().as_slice(),
        &[vec![99], vec![2], vec![1]],
        "10ms deadline dispatches before the earlier-submitted 100ms one"
    );
}

/// FIFO tie-break: a large deadline-less batch admitted early is served
/// ahead of singletons submitted after it — no starvation by small
/// requests.
#[test]
fn big_batch_is_not_starved_by_later_singletons() {
    let clock = Arc::new(VirtualClock::new());
    let log = Arc::new(Mutex::new(Vec::new()));
    let (exec, gate, entered) = gated(Arc::clone(&log));
    let server = AsyncServer::start_with_clock(
        ServingConfig { max_batch: 2, ..cfg() },
        clock,
        move || exec,
    );
    // two ids so the gate wave closes by size (the clock is frozen)
    let g = server.submit(&[98, 99], SubmitOpts::default()).unwrap();
    entered.recv_timeout(RECV).unwrap();
    let big = server.submit(&[10, 11, 12, 13, 14, 15], SubmitOpts::default()).unwrap();
    let s1 = server.submit(&[20], SubmitOpts::default()).unwrap();
    let s2 = server.submit(&[21], SubmitOpts::default()).unwrap();
    for _ in 0..8 {
        let _ = gate.send(());
    }
    assert!(g.recv_timeout(RECV).unwrap().is_ok());
    let rows = big.recv_timeout(RECV).unwrap().unwrap();
    assert_eq!(rows.len(), 6, "the whole batch is reassembled across rounds");
    assert!(s1.recv_timeout(RECV).unwrap().is_ok());
    assert!(s2.recv_timeout(RECV).unwrap().is_ok());
    let _ = server.shutdown();
    let flat: Vec<u32> = log.lock().unwrap().iter().flatten().copied().collect();
    assert_eq!(
        flat,
        vec![98, 99, 10, 11, 12, 13, 14, 15, 20, 21],
        "the early big batch dispatches fully before later singletons"
    );
}

/// Class 0 is strictly more urgent: it overtakes queued class-1 work
/// regardless of submission order.
#[test]
fn class_zero_overtakes_class_one() {
    let clock = Arc::new(VirtualClock::new());
    let log = Arc::new(Mutex::new(Vec::new()));
    let (exec, gate, entered) = gated(Arc::clone(&log));
    let server = AsyncServer::start_with_clock(
        ServingConfig { max_batch: 1, priority_lanes: 2, ..cfg() },
        clock,
        move || exec,
    );
    let g = server.submit(&[99], SubmitOpts::class(1)).unwrap();
    entered.recv_timeout(RECV).unwrap();
    let background = server.submit(&[1], SubmitOpts::class(1)).unwrap();
    let urgent = server.submit(&[2], SubmitOpts::class(0)).unwrap();
    for _ in 0..3 {
        let _ = gate.send(());
    }
    for rx in [g, urgent, background] {
        assert!(rx.recv_timeout(RECV).unwrap().is_ok());
    }
    let stats = server.shutdown();
    assert_eq!(
        log.lock().unwrap().as_slice(),
        &[vec![99], vec![2], vec![1]],
        "class 0 dispatches before earlier class-1 work"
    );
    assert_eq!(stats.classes[0].requests, 1);
    assert_eq!(stats.classes[1].requests, 2);
}

// ------------------------------------------------------------- telemetry

/// On the virtual clock, throughput is exact arithmetic: 4 ids over one
/// advanced second is 4.0 ids/s, in aggregate and in the class row.
#[test]
fn virtual_clock_makes_throughput_deterministic() {
    let clock = Arc::new(VirtualClock::new());
    let server = AsyncServer::start_with_clock(cfg(), Arc::clone(&clock), || echo);
    let rxs: Vec<_> =
        (0..4).map(|i| server.submit(&[i], SubmitOpts::default()).unwrap()).collect();
    for rx in rxs {
        assert!(rx.recv_timeout(RECV).unwrap().is_ok());
    }
    clock.advance(Duration::from_secs(1));
    let stats = server.shutdown();
    assert!((stats.throughput_rps - 4.0).abs() < 1e-9, "got {}", stats.throughput_rps);
    assert!((stats.classes[0].qps - 4.0).abs() < 1e-9);
    assert_eq!(stats.classes[0].submitted, 4);
    assert_eq!(stats.classes[0].completed, 4);
}

// ------------------------------------------------------ overload shedding

/// Sustained over-capacity load: the queue depth stays bounded by
/// `queue_cap`, excess submissions shed with typed errors, every
/// admitted request still completes, and the class percentiles come out
/// ordered and non-degenerate. (Real clock: this is a load test, the
/// *outcome* bounds are deterministic even though timing is not.)
#[test]
fn over_capacity_load_is_shed_typed_and_bounded() {
    let config = ServingConfig {
        max_batch: 4,
        flush_after: Duration::from_millis(1),
        queue_cap: 8,
        admission_qps: Some(2000.0),
        admission_burst: Some(8.0),
        priority_lanes: 1,
        ..Default::default()
    };
    let server = AsyncServer::start(config, |ids: &[u32]| -> Result<Vec<Vec<f32>>> {
        std::thread::sleep(Duration::from_micros(200)); // ~capacity limiter
        echo(ids)
    });
    let mut accepted = Vec::new();
    let (mut overloaded, mut queue_full) = (0u64, 0u64);
    for i in 0..400u32 {
        match server.submit(&[i], SubmitOpts::default()) {
            Ok(rx) => accepted.push(rx),
            Err(ServeError::Overloaded { .. }) => overloaded += 1,
            Err(ServeError::QueueFull { queued, cap }) => {
                assert!(queued <= cap, "reject reports a within-bound depth");
                queue_full += 1;
            }
            Err(other) => panic!("unexpected admission error: {other:?}"),
        }
    }
    assert!(!accepted.is_empty(), "some of the offered load must be admitted");
    assert!(overloaded + queue_full > 0, "400 rushed singles must overload admission");
    for rx in accepted {
        assert!(rx.recv_timeout(RECV).unwrap().is_ok(), "admitted requests complete");
    }
    let stats = server.shutdown();
    assert_eq!(stats.rejected_overloaded, overloaded);
    assert_eq!(stats.rejected_queue_full, queue_full);
    assert!(stats.peak_queued <= 8, "queue never exceeds cap: {}", stats.peak_queued);
    let c = &stats.classes[0];
    assert!(c.p50_ns > 0, "real-clock latencies are nonzero");
    assert!(c.p50_ns <= c.p95_ns && c.p95_ns <= c.p99_ns, "percentiles are ordered");
    assert!(c.max_ns >= c.p99_ns);
}

// ------------------------------------------------------------ bit-identity

fn ci_builder(model: ModelId, shards: Option<usize>, reuse: bool) -> SessionBuilder {
    let mut b = Session::builder()
        .dataset(DatasetId::Imdb)
        .scale(DatasetScale::ci())
        .model(model)
        .sampling(SamplingSpec::uniform(usize::MAX, 1));
    if let Some(k) = shards {
        b = b.partition(PartitionSpec::new(k));
    }
    if reuse {
        b = b.reuse(ReuseSpec::rows(1 << 14));
    }
    b
}

/// Mirror of the dispatcher's lane-grouped chunking against a plain
/// session: group positions by owner lane, dispatch rounds of ≤`cap`
/// ids per lane through `run_batch`, reassemble by position. With one
/// lane this degenerates to a single `run_batch` call.
fn sync_oracle(session: &mut Session, ids: &[u32], lanes: usize, cap: usize) -> Vec<Vec<f32>> {
    if lanes <= 1 {
        return session.run_batch(ids).unwrap();
    }
    let mut groups: Vec<Vec<usize>> = vec![Vec::new(); lanes];
    for (pos, &id) in ids.iter().enumerate() {
        groups[session.shard_of(id).unwrap_or(0).min(lanes - 1)].push(pos);
    }
    let mut slots: Vec<Option<Vec<f32>>> = ids.iter().map(|_| None).collect();
    let rounds = groups.iter().map(|g| g.len().div_ceil(cap)).max().unwrap_or(0);
    for round in 0..rounds {
        let chunk: Vec<usize> = groups
            .iter()
            .flat_map(|g| g.iter().skip(round * cap).take(cap).copied())
            .collect();
        let chunk_ids: Vec<u32> = chunk.iter().map(|&p| ids[p]).collect();
        for (&p, row) in chunk.iter().zip(session.run_batch(&chunk_ids).unwrap()) {
            slots[p] = Some(row);
        }
    }
    slots.into_iter().map(|r| r.expect("every position covered")).collect()
}

/// The headline acceptance: async replies are bit-identical to the
/// synchronous `run_batch` path for every model × shards {1,2} × reuse
/// on/off. Requests are awaited one at a time so both sides execute the
/// same dispatch sequence (which is what pins reuse-cache evolution).
#[test]
fn async_replies_match_sync_path_bit_identically() {
    let batches: [&[u32]; 3] =
        [&[0, 1, 2, 3, 4, 5], &[2, 3, 8, 9], &[0, 1, 2, 3, 4, 5]];
    for model in [ModelId::Rgcn, ModelId::Han, ModelId::Magnn] {
        for shards in [None, Some(2)] {
            for reuse in [false, true] {
                let lanes = shards.unwrap_or(1);
                let mut sync = ci_builder(model, shards, reuse).build().unwrap();
                let server = ci_builder(model, shards, reuse).serve_async(ServingConfig {
                    max_batch: 16,
                    flush_after: Duration::from_millis(1),
                    priority_lanes: 1,
                    ..Default::default()
                });
                for ids in batches {
                    let rx = server.submit(ids, SubmitOpts::default()).unwrap();
                    let got = rx.recv_timeout(RECV).unwrap().unwrap();
                    let want = sync_oracle(&mut sync, ids, lanes, 16);
                    assert_eq!(
                        got, want,
                        "{model:?} shards={shards:?} reuse={reuse}: async reply \
                         must be bit-identical to the sync path"
                    );
                }
                let stats = server.shutdown();
                assert_eq!(stats.completed, 16, "6+4+6 ids across the three batches");
                if reuse {
                    let r = stats.reuse.expect("reuse stats surface through serving");
                    assert!(
                        r.proj_hits + r.agg_hits > 0,
                        "{model:?} shards={shards:?}: repeated batch must hit the cache"
                    );
                }
            }
        }
    }
}
