//! Session API integration: every `SchedulePolicy` variant × both
//! in-tree backends is reachable through one `Session`, and the serving
//! path executes batches through a session (the ISSUE-1 acceptance
//! matrix).

use std::time::Duration;

use hgnn_char::datasets::{DatasetId, DatasetScale};
use hgnn_char::models::ModelId;
use hgnn_char::profiler::StageId;
use hgnn_char::session::{
    BackendSpec, ExecBackend, NativeBackend, Profiling, SchedulePolicy, ServeConfig, Session,
    SessionBuilder,
};

fn ci_builder() -> SessionBuilder {
    Session::builder()
        .dataset(DatasetId::Imdb)
        .scale(DatasetScale::ci())
        .model(ModelId::Han)
}

#[test]
fn every_policy_runs_on_the_native_backend() {
    let mut session = ci_builder().build().unwrap();
    let baseline = session.run().unwrap();
    assert!(baseline.output.frob_norm() > 0.0);
    for policy in SchedulePolicy::all(3) {
        session.set_schedule(policy);
        let run = session.run().unwrap();
        assert!(
            run.output.allclose(&baseline.output, 1e-3, 1e-4),
            "{} diverges from sequential",
            policy.label()
        );
        assert!(!run.profile.kernels.is_empty(), "{}: empty profile", policy.label());
        assert_eq!(run.report.policy, policy);
        // modeled makespan never exceeds the modeled serial total
        assert!(
            run.report.modeled_makespan_ns <= run.report.modeled_serial_ns + 1.0,
            "{}: makespan above serial",
            policy.label()
        );
    }
}

#[test]
fn every_model_runs_through_a_session() {
    for model in [ModelId::Rgcn, ModelId::Han, ModelId::Magnn] {
        for dataset in DatasetId::HETERO {
            let run = Session::builder()
                .dataset(dataset)
                .scale(DatasetScale::ci())
                .model(model)
                .schedule(SchedulePolicy::InterSubgraphParallel { workers: 2 })
                .build()
                .unwrap()
                .run()
                .unwrap();
            assert!(
                run.output.frob_norm() > 0.0,
                "{model:?}/{dataset:?} produced a zero output"
            );
        }
    }
}

#[test]
fn backend_spec_custom_box_is_reachable() {
    // a user-supplied backend (here: the native one behind a box) flows
    // through the same Session plumbing as the built-ins
    let custom: Box<dyn ExecBackend + Send> =
        Box::new(NativeBackend::new().with_traces(true));
    let mut session = ci_builder().backend(BackendSpec::Custom(custom)).build().unwrap();
    assert_eq!(session.backend_name(), "native");
    let run = session.run().unwrap();
    assert!(run.profile.kernels.iter().any(|k| k.exec.trace.is_some()));
}

#[test]
fn pjrt_backend_via_session_when_artifacts_exist() {
    // Mirrors integration_runtime's skip conditions: without the `pjrt`
    // feature or without artifacts this test only asserts clean errors.
    let root = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    let have = cfg!(feature = "pjrt") && root.join("manifest.json").exists();
    for policy in SchedulePolicy::all(2) {
        let built = ci_builder().pjrt(root.clone()).schedule(policy).build();
        if !have {
            // stub/missing-artifact paths must error (at build or first
            // run), never panic
            if let Ok(mut s) = built {
                assert!(s.run().is_err());
            }
            continue;
        }
        let mut session = built.expect("PJRT session");
        assert_eq!(session.backend_name(), "pjrt");
        assert!(session.backend_caps().whole_model);
        let run = session.run().unwrap_or_else(|e| panic!("{}: {e}", policy.label()));
        // whole-model artifact: fused execution, no staged profile
        assert!(run.output.as_slice().iter().all(|v| v.is_finite()));
        assert!(run.na_results.is_empty());
        // and the output agrees loosely with native (ELL truncation)
        let native = ci_builder().build().unwrap().run().unwrap();
        assert_eq!(run.output.shape(), native.output.shape());
    }
}

#[test]
fn server_executes_batches_through_session() {
    let server = ci_builder()
        .schedule(SchedulePolicy::InterSubgraphParallel { workers: 2 })
        .serve(ServeConfig::default());
    let rxs: Vec<_> = (0..24).map(|i| server.submit(i).unwrap()).collect();
    let mut rows = Vec::new();
    for rx in rxs {
        rows.push(rx.recv_timeout(Duration::from_secs(60)).unwrap());
    }
    let stats = server.shutdown();
    assert_eq!(stats.completed, 24);
    assert!(stats.throughput_rps > 0.0);
    // all rows have the hidden dimension and finite values
    assert!(rows.iter().all(|r| !r.is_empty() && r.iter().all(|v| v.is_finite())));
    // id wrapping: same node id => same embedding row
    let server = ci_builder().serve(ServeConfig::default());
    let a = server.submit(5).unwrap().recv_timeout(Duration::from_secs(60)).unwrap();
    let b = server.submit(5).unwrap().recv_timeout(Duration::from_secs(60)).unwrap();
    drop(server);
    assert_eq!(a, b);
}

#[test]
fn profiling_levels_compose_with_policies() {
    for policy in [SchedulePolicy::Sequential, SchedulePolicy::InterSubgraphParallel { workers: 2 }] {
        let mut traced = ci_builder()
            .schedule(policy)
            .profiling(Profiling::Traces)
            .build()
            .unwrap();
        let run = traced.run().unwrap();
        let na_traced = run
            .profile
            .kernels
            .iter()
            .filter(|k| k.stage == StageId::NeighborAggregation)
            .any(|k| k.exec.trace.is_some());
        assert!(na_traced, "{}: no NA gather traces recorded", policy.label());
    }
}
