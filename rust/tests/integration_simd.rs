//! Integration tests for the raw-speed kernel tier: the SIMD lane-array
//! microkernels and the packed-B sgemm core must be **bit-identical** to
//! the scalar/naive oracles for every model, thread count and shard
//! count — including feature widths that are not multiples of the SIMD
//! lane width — and the packed-panel cache must invalidate on weight
//! swaps. The opt-in quantized feature-projection path trades that
//! bit-identity for bounded, measured logit error, verified here at both
//! the row level (property) and the session level (integration).

use hgnn_char::datasets::{DatasetId, DatasetScale};
use hgnn_char::graph::Csr;
use hgnn_char::kernels::dense::{sgemm, sgemm_cached, sgemm_naive, GemmBlocking, PackKey};
use hgnn_char::kernels::quant::{QuantRow, QuantSpec};
use hgnn_char::kernels::simd;
use hgnn_char::kernels::sparse_ops::{spmm_csr, SpmmReduce};
use hgnn_char::kernels::Ctx;
use hgnn_char::models::ModelId;
use hgnn_char::parallel;
use hgnn_char::reuse::ReuseSpec;
use hgnn_char::sampler::SamplingSpec;
use hgnn_char::session::{PartitionSpec, Session, SessionBuilder};
use hgnn_char::tensor::Tensor;
use hgnn_char::util::Pcg32;

fn ci_builder(model: ModelId) -> SessionBuilder {
    Session::builder()
        .dataset(DatasetId::Imdb)
        .scale(DatasetScale::ci())
        .model(model)
}

/// The tentpole contract: SIMD-ized kernels change nothing observable in
/// f32 — every model's forward is bitwise identical across thread counts
/// {1, 4} and shard counts {1, 2}.
#[test]
fn forward_bit_identical_across_models_threads_and_shards() {
    for model in [ModelId::Rgcn, ModelId::Han, ModelId::Magnn] {
        let base = ci_builder(model).threads(1).build().unwrap().run().unwrap();
        for t in [1usize, 4] {
            for shards in [None, Some(2usize)] {
                let mut b = ci_builder(model).threads(t);
                if let Some(k) = shards {
                    b = b.partition(PartitionSpec::new(k).with_threads(k));
                }
                let run = b.build().unwrap().run().unwrap();
                assert!(
                    run.output.allclose(&base.output, 0.0, 0.0),
                    "{model:?} output at {t} thread(s), {shards:?} shards diverges"
                );
            }
        }
    }
}

/// Lane-array microkernels vs inline scalar oracles at feature widths
/// that straddle the 8-lane boundary (9 and 13 exercise the remainder
/// loops; 16 the exact-multiple path).
#[test]
fn simd_microkernels_bit_identical_to_scalar_at_ragged_widths() {
    let mut rng = Pcg32::seeded(41);
    for f in [9usize, 13, 16] {
        let x: Vec<f32> = (0..f).map(|_| rng.gen_f32() - 0.5).collect();
        let s = rng.gen_f32() + 0.5;
        let init: Vec<f32> = (0..f).map(|_| rng.gen_f32()).collect();

        let mut got = init.clone();
        simd::axpy(&mut got, s, &x);
        let mut want = init.clone();
        for (o, &b) in want.iter_mut().zip(&x) {
            *o += s * b;
        }
        assert_eq!(got, want, "axpy f={f}");

        let mut got = init.clone();
        simd::add_assign(&mut got, &x);
        let mut want = init.clone();
        for (o, &b) in want.iter_mut().zip(&x) {
            *o += b;
        }
        assert_eq!(got, want, "add_assign f={f}");

        let mut got = init.clone();
        simd::scale(&mut got, s);
        let want: Vec<f32> = init.iter().map(|&v| v * s).collect();
        assert_eq!(got, want, "scale f={f}");

        let (mut g0, mut g1) = (init.clone(), x.clone());
        simd::axpy2(&mut g0, &mut g1, s, 2.0 * s, &x);
        let (mut w0, mut w1) = (init.clone(), x.clone());
        for ((o0, o1), &b) in w0.iter_mut().zip(w1.iter_mut()).zip(&x) {
            *o0 += s * b;
            *o1 += 2.0 * s * b;
        }
        assert_eq!(g0, w0, "axpy2 row0 f={f}");
        assert_eq!(g1, w1, "axpy2 row1 f={f}");
    }
}

/// `sgemm` (SIMD panel) vs `sgemm_naive` at K/N that are not multiples
/// of the lane width, serial and at 4 pool threads — bitwise, because
/// the lane temporaries replay the scalar per-element operation order.
#[test]
fn sgemm_bit_identical_to_naive_at_ragged_shapes_and_threads() {
    let mut rng = Pcg32::seeded(42);
    for (m, k, n) in [(1usize, 1usize, 1usize), (17, 13, 9), (33, 16, 29), (65, 130, 31)] {
        let a = Tensor::randn(m, k, 1.0, &mut rng);
        let b = Tensor::randn(k, n, 1.0, &mut rng);
        let want = sgemm_naive(&a, &b);
        for t in [1usize, 4] {
            let got = parallel::with_threads(t, || {
                let mut ctx = Ctx::default();
                sgemm(&mut ctx, &a, &b, GemmBlocking::default()).unwrap()
            });
            assert!(
                got.allclose(&want, 0.0, 0.0),
                "sgemm {m}x{k}x{n} at {t} thread(s) diverges from naive"
            );
        }
    }
}

/// `spmm_csr` (SIMD accumulation) vs an inline scalar oracle at ragged
/// feature widths, weighted and unweighted, serial and parallel.
#[test]
fn spmm_bit_identical_to_scalar_oracle_at_ragged_widths() {
    let mut rng = Pcg32::seeded(43);
    let n = 37usize;
    // ring + a skip edge per node: deterministic, degree 2
    let mut indptr = vec![0u32];
    let mut indices = Vec::new();
    for d in 0..n {
        indices.push(((d + 1) % n) as u32);
        indices.push(((d + 7) % n) as u32);
        indptr.push(indices.len() as u32);
    }
    let adj = Csr { n_rows: n, n_cols: n, indptr, indices };
    let weights: Vec<f32> = (0..adj.nnz()).map(|_| rng.gen_f32() + 0.1).collect();
    for f in [9usize, 13] {
        let x = Tensor::randn(n, f, 1.0, &mut rng);
        let xs = x.as_slice();
        // scalar oracle: same edge order, same accumulation order
        let mut want = Tensor::zeros(n, f);
        for d in 0..n {
            let (lo, hi) = (adj.indptr[d] as usize, adj.indptr[d + 1] as usize);
            for e in lo..hi {
                let s = adj.indices[e] as usize;
                for j in 0..f {
                    let v = want.get(d, j) + weights[e] * xs[s * f + j];
                    want.set(d, j, v);
                }
            }
        }
        for t in [1usize, 4] {
            let got = parallel::with_threads(t, || {
                let mut ctx = Ctx::default();
                spmm_csr(&mut ctx, &adj, &x, Some(&weights), SpmmReduce::Sum).unwrap()
            });
            assert!(
                got.allclose(&want, 0.0, 0.0),
                "weighted spmm f={f} at {t} thread(s) diverges from scalar oracle"
            );
        }
    }
}

/// The packed-panel cache serves repeat projections without repacking
/// and matches the unpacked kernel bitwise at ragged shapes.
#[test]
fn packed_sgemm_cache_bit_identical_and_reused() {
    let mut rng = Pcg32::seeded(44);
    let a = Tensor::randn(23, 13, 1.0, &mut rng);
    let b = Tensor::randn(13, 9, 1.0, &mut rng);
    let mut ctx = Ctx::default();
    let blk = GemmBlocking::default();
    let want = sgemm(&mut ctx, &a, &b, blk).unwrap();
    let o1 = sgemm_cached(&mut ctx, &a, &b, PackKey::Proj(0), blk).unwrap();
    let o2 = sgemm_cached(&mut ctx, &a, &b, PackKey::Proj(0), blk).unwrap();
    assert!(o1.allclose(&want, 0.0, 0.0));
    assert!(o2.allclose(&want, 0.0, 0.0));
    assert_eq!(ctx.packs.len(), 1, "repeat call must reuse the resident panel");
}

/// Weight swaps must drop every resident packed panel
/// (`Session::set_weights` -> `Session::invalidate`), and the post-swap
/// forward must match a cold session built under the new weights.
#[test]
fn packed_panels_invalidate_on_set_weights() {
    let mut s = ci_builder(ModelId::Han).build().unwrap();
    let _ = s.run().unwrap();
    assert!(s.packed_panels() > 0, "the forward must leave FP panels resident");
    s.init_weights(1234).unwrap();
    assert_eq!(s.packed_panels(), 0, "set_weights must drop every packed panel");
    let run = s.run().unwrap();
    assert!(s.packed_panels() > 0);
    let mut cold = ci_builder(ModelId::Han).build().unwrap();
    cold.init_weights(1234).unwrap();
    let cold_run = cold.run().unwrap();
    assert!(
        run.output.allclose(&cold_run.output, 0.0, 0.0),
        "post-swap forward diverges from a cold session with the same weights"
    );
}

/// Property: one quantization round-trip keeps every row element within
/// the format's worst-case step (int8: half a per-row step; f16: 2^-10
/// relative).
#[test]
fn quant_row_roundtrip_error_bounded_property() {
    let mut rng = Pcg32::seeded(45);
    let mut dq = Vec::new();
    for len in [1usize, 7, 64, 129] {
        for trial in 0..20 {
            let row: Vec<f32> = (0..len).map(|_| (rng.gen_f32() - 0.5) * 20.0).collect();
            let max_abs = row.iter().fold(0.0f32, |m, v| m.max(v.abs()));
            QuantRow::quantize(&row, QuantSpec::Int8).dequantize_into(&mut dq);
            let step = max_abs / 127.0;
            for (g, w) in dq.iter().zip(&row) {
                assert!(
                    (g - w).abs() <= 0.5 * step + 1e-6,
                    "int8 len={len} trial={trial}: |{g} - {w}| > step/2 ({step})"
                );
            }
            QuantRow::quantize(&row, QuantSpec::F16).dequantize_into(&mut dq);
            for (g, w) in dq.iter().zip(&row) {
                assert!(
                    (g - w).abs() <= w.abs() * 9.8e-4 + 1e-7,
                    "f16 len={len} trial={trial}: |{g} - {w}| too large"
                );
            }
        }
    }
}

/// Integration thresholds for the quantized feature-projection path:
/// the session-level logit error vs the f32 baseline stays within 2%
/// (f16) / 20% (int8) of the baseline's max logit magnitude — orders of
/// magnitude above the per-weight rounding error, so the bound is loose
/// enough to be robust yet tight enough to catch a broken scale or a
/// double-quantized panel.
#[test]
fn quantized_forward_logit_error_bounded() {
    let base = ci_builder(ModelId::Han).build().unwrap().run().unwrap();
    let base_max = base
        .output
        .as_slice()
        .iter()
        .fold(0.0f32, |m, v| m.max(v.abs()))
        .max(1.0);
    for (spec, rel) in [(QuantSpec::F16, 0.02f32), (QuantSpec::Int8, 0.2f32)] {
        let run = ci_builder(ModelId::Han).quantize(spec).build().unwrap().run().unwrap();
        assert_eq!(run.output.shape(), base.output.shape());
        let max_err = run.output.max_abs_diff(&base.output);
        assert!(
            max_err <= rel * base_max,
            "{spec:?}: max logit err {max_err} exceeds {rel} x base max {base_max}"
        );
        assert!(
            max_err > 0.0,
            "{spec:?}: quantization changed nothing — the path is not wired"
        );
        // determinism: quantized weights are a fixed function of the f32
        // weights, so a second quantized session reproduces exactly
        let again = ci_builder(ModelId::Han).quantize(spec).build().unwrap().run().unwrap();
        assert!(again.output.allclose(&run.output, 0.0, 0.0));
        // the report renders the delta without panicking
        let table = hgnn_char::report::quant_delta_table(spec.name(), &base.output, &run.output);
        assert!(table.contains(spec.name()));
    }
}

fn quant_batches(
    quant: Option<QuantSpec>,
    threads: usize,
    shards: Option<usize>,
) -> Vec<Vec<Vec<f32>>> {
    let mut builder = ci_builder(ModelId::Han)
        .sampling(SamplingSpec::uniform(usize::MAX, 1))
        .reuse(ReuseSpec::rows(1 << 12))
        .threads(threads);
    if let Some(spec) = quant {
        builder = builder.quantize(spec);
    }
    if let Some(k) = shards {
        builder = builder.partition(PartitionSpec::new(k).with_threads(k));
    }
    let mut s = builder.build().unwrap();
    let ids = [0u32, 5, 9, 1, 5, 3];
    vec![s.run_batch(&ids).unwrap(), s.run_batch(&ids).unwrap()]
}

/// Quantized serving composed with reuse caching and sharding: cold and
/// warm batches stay deterministic across threads {1, 4} and shards
/// {1, 2} (quantization is a fixed function of the cached values), and
/// the warm batch — which substitutes dequantized cache rows — stays
/// within the integration error bound of the f32 session instead of
/// being bit-identical.
#[test]
fn quantized_serving_composes_with_reuse_and_shards() {
    let f32_base = quant_batches(None, 1, None);
    assert_eq!(f32_base[0], f32_base[1], "f32 warm batch must stay bit-identical");
    let base = quant_batches(Some(QuantSpec::Int8), 1, None);
    for t in [1usize, 4] {
        for shards in [None, Some(2usize)] {
            let got = quant_batches(Some(QuantSpec::Int8), t, shards);
            assert_eq!(
                got, base,
                "int8 serving at {t} thread(s), {shards:?} shards must be deterministic"
            );
        }
    }
    let flat_max = |b: &Vec<Vec<Vec<f32>>>| {
        b.iter()
            .flatten()
            .flatten()
            .fold(0.0f32, |m, v| m.max(v.abs()))
            .max(1.0)
    };
    let bound = 0.2 * flat_max(&f32_base);
    for (batch, (q, f)) in base.iter().zip(&f32_base).enumerate() {
        for (qr, fr) in q.iter().zip(f) {
            for (a, b) in qr.iter().zip(fr) {
                assert!(
                    (a - b).abs() <= bound,
                    "int8 batch {batch} drifts {} from f32 (bound {bound})",
                    (a - b).abs()
                );
            }
        }
    }
}
