//! Training-subsystem integration (the ISSUE-9 acceptance criteria):
//! finite-difference gradient checks per backward stage (FP / NA / SA)
//! across the models, fused-vs-unfused backward equivalence with a
//! strictly lower dispatch count, seeded-epoch determinism across
//! thread counts and shard layouts, and a monotonically decreasing
//! full-batch fit.

use hgnn_char::datasets::{self, DatasetId, DatasetScale};
use hgnn_char::graph::HeteroGraph;
use hgnn_char::models::{self, ModelConfig, ModelId, ModelPlan, ModelWeights};
use hgnn_char::partition::PartitionSpec;
use hgnn_char::sampler::SamplingSpec;
use hgnn_char::session::{ExecBackend, NativeBackend, Session, SessionBuilder};
use hgnn_char::tensor::Tensor;
use hgnn_char::train::{self, OptimizerSpec, TrainConfig};
use hgnn_char::util::Pcg32;

fn setup(model: ModelId) -> (HeteroGraph, ModelPlan) {
    let hg = datasets::build(DatasetId::Imdb, &DatasetScale::ci()).unwrap();
    let plan = models::build_plan(model, &hg, &ModelConfig::default()).unwrap();
    (hg, plan)
}

/// A deterministic classifier head + batch + labels for loss checks.
fn task(plan: &ModelPlan, hg: &HeteroGraph) -> (Tensor, Vec<u32>, Vec<u32>) {
    let hidden = plan.config.hidden_dim;
    let classes = 4;
    let mut rng = Pcg32::new(7, 1);
    let head = Tensor::randn(hidden, classes, (1.0 / hidden as f32).sqrt(), &mut rng);
    let count = hg.node_type(plan.target).count;
    let rows: Vec<u32> = (0..count.min(16) as u32).collect();
    let labels: Vec<u32> = rows.iter().map(|&g| train::synthetic_label(5, g, classes)).collect();
    (head, rows, labels)
}

fn loss_of(plan: &ModelPlan, hg: &HeteroGraph, head: &Tensor, rows: &[u32], labels: &[u32]) -> f64 {
    let backend = NativeBackend::new();
    let mut ctx = backend.make_ctx();
    train::run_batch(&backend, &mut ctx, plan, hg, head, rows, labels, true).unwrap().loss
}

/// Stage tag per parameter group, in [`ModelWeights::params`] order
/// (proj + embed = FP, attention vectors = NA, semantic MLP = SA).
fn stage_tags(w: &ModelWeights) -> Vec<&'static str> {
    let mut v: Vec<&'static str> = Vec::new();
    for _ in 0..w.proj.len() + w.embed.len() {
        v.push("FP");
    }
    for _ in 0..w.attn_l.len() + w.attn_r.len() + w.inst_attn.len() {
        v.push("NA");
    }
    if w.sem_w.is_some() {
        v.push("SA");
    }
    v.push("SA");
    if w.sem_q.is_some() {
        v.push("SA");
    }
    v
}

/// Central finite difference along the analytic gradient direction of
/// one backward stage: perturbing every parameter of the stage by
/// `±eps·g/‖g‖` must change the loss by `±eps·‖g‖` to first order, so
/// the measured slope pins both the direction and the magnitude of the
/// stage's gradients.
fn fd_check_stages(model: ModelId) {
    let (hg, plan) = setup(model);
    let (head, rows, labels) = task(&plan, &hg);
    let backend = NativeBackend::new();
    let mut ctx = backend.make_ctx();
    let res =
        train::run_batch(&backend, &mut ctx, &plan, &hg, &head, &rows, &labels, true).unwrap();
    let tags = stage_tags(&plan.weights);
    let eps = 2e-2f64;

    let mut checked = 0;
    for stage in ["FP", "NA", "SA"] {
        let g_groups = res.grads.weights.params();
        assert_eq!(g_groups.len(), tags.len(), "{model:?}: tag/group arity");
        let idxs: Vec<usize> = (0..tags.len())
            .filter(|&i| tags[i] == stage && !g_groups[i].is_empty())
            .collect();
        if idxs.is_empty() {
            continue; // e.g. R-GCN has no NA/SA parameters
        }
        let norm: f64 = idxs
            .iter()
            .map(|&i| g_groups[i].iter().map(|&g| (g as f64) * (g as f64)).sum::<f64>())
            .sum::<f64>()
            .sqrt();
        assert!(norm > 1e-6, "{model:?} {stage}: gradient is (near) zero — stage not wired?");

        let perturbed = |sign: f64| -> f64 {
            let mut w = plan.weights.clone();
            {
                let mut groups = w.params_mut();
                for &i in &idxs {
                    for (x, &g) in groups[i].iter_mut().zip(g_groups[i]) {
                        *x += (sign * eps * (g as f64) / norm) as f32;
                    }
                }
            }
            let p = ModelPlan { weights: w, ..plan.clone() };
            loss_of(&p, &hg, &head, &rows, &labels)
        };
        let fd = (perturbed(1.0) - perturbed(-1.0)) / (2.0 * eps);
        let rel = (fd - norm).abs() / norm.max(fd.abs()).max(1e-3);
        assert!(
            rel <= 1e-3,
            "{model:?} {stage}: FD slope {fd:.6e} vs analytic ‖g‖ {norm:.6e} (rel {rel:.2e})"
        );
        checked += 1;
    }
    assert!(checked > 0, "{model:?}: no stage had parameters to check");
}

#[test]
fn fd_gradients_rgcn() {
    fd_check_stages(ModelId::Rgcn);
}

#[test]
fn fd_gradients_han() {
    fd_check_stages(ModelId::Han);
}

#[test]
fn fd_gradients_magnn() {
    fd_check_stages(ModelId::Magnn);
}

/// The classifier-head gradient from the loss backward obeys the same
/// finite-difference identity.
#[test]
fn fd_gradient_classifier_head() {
    let (hg, plan) = setup(ModelId::Han);
    let (head, rows, labels) = task(&plan, &hg);
    let backend = NativeBackend::new();
    let mut ctx = backend.make_ctx();
    let res =
        train::run_batch(&backend, &mut ctx, &plan, &hg, &head, &rows, &labels, true).unwrap();
    let g = res.head_grad;
    let norm: f64 = g.as_slice().iter().map(|&v| (v as f64) * (v as f64)).sum::<f64>().sqrt();
    assert!(norm > 1e-6);
    let eps = 2e-2f64;
    let perturbed = |sign: f64| -> f64 {
        let mut h = head.clone();
        for (x, &d) in h.as_mut_slice().iter_mut().zip(g.as_slice()) {
            *x += (sign * eps * (d as f64) / norm) as f32;
        }
        loss_of(&plan, &hg, &h, &rows, &labels)
    };
    let fd = (perturbed(1.0) - perturbed(-1.0)) / (2.0 * eps);
    let rel = (fd - norm).abs() / norm.max(fd.abs()).max(1e-3);
    assert!(rel <= 1e-3, "head: FD {fd:.6e} vs ‖g‖ {norm:.6e} (rel {rel:.2e})");
}

/// Fusing the backward kernel swarm must not change a single gradient
/// bit — only the dispatch count, which drops strictly below the
/// unfused count whenever the model has more than one subgraph.
#[test]
fn fused_backward_matches_unfused_with_fewer_dispatches() {
    for model in [ModelId::Rgcn, ModelId::Han, ModelId::Magnn] {
        let (hg, plan) = setup(model);
        let (head, rows, labels) = task(&plan, &hg);
        let backend = NativeBackend::new();
        let mut ctx = backend.make_ctx();
        let fused =
            train::run_batch(&backend, &mut ctx, &plan, &hg, &head, &rows, &labels, true).unwrap();
        let unfused = train::run_batch(&backend, &mut ctx, &plan, &hg, &head, &rows, &labels, false)
            .unwrap();
        assert_eq!(fused.loss.to_bits(), unfused.loss.to_bits(), "{model:?} loss");
        for (a, b) in
            fused.grads.weights.params().iter().zip(unfused.grads.weights.params().iter())
        {
            assert_eq!(a, b, "{model:?}: fused/unfused gradients diverge");
        }
        assert!(
            fused.backward_dispatches < unfused.backward_dispatches,
            "{model:?}: fused {} !< unfused {} backward dispatches",
            fused.backward_dispatches,
            unfused.backward_dispatches
        );
    }
}

fn ci_builder(model: ModelId) -> SessionBuilder {
    Session::builder().dataset(DatasetId::Imdb).scale(DatasetScale::ci()).model(model)
}

fn weights_equal(a: &ModelWeights, b: &ModelWeights) -> bool {
    let (ga, gb) = (a.params(), b.params());
    ga.len() == gb.len() && ga.iter().zip(&gb).all(|(x, y)| x == y)
}

/// Seeded weight init is a pure function of the seed: two sessions
/// seeded alike produce bit-identical weights and outputs; a different
/// seed produces different weights.
#[test]
fn init_weights_is_seed_deterministic() {
    let mut a = ci_builder(ModelId::Han).build().unwrap();
    let mut b = ci_builder(ModelId::Han).build().unwrap();
    a.init_weights(42).unwrap();
    b.init_weights(42).unwrap();
    assert!(weights_equal(&a.plan().weights, &b.plan().weights));
    let (ra, rb) = (a.run().unwrap(), b.run().unwrap());
    assert!(ra.output.allclose(&rb.output, 0.0, 0.0));
    b.init_weights(43).unwrap();
    assert!(!weights_equal(&a.plan().weights, &b.plan().weights));
}

/// A seeded 3-epoch full-batch fit decreases the loss monotonically
/// (plain gradient descent: momentum off, small step, one batch per
/// epoch, loss measured before each step).
#[test]
fn full_batch_fit_decreases_loss_monotonically() {
    let config = TrainConfig {
        epochs: 3,
        batch: usize::MAX,
        optimizer: OptimizerSpec::Sgd { lr: 0.01, momentum: 0.0 },
        seed: 0xBEEF,
        classes: 4,
        fused: true,
    };
    for model in [ModelId::Rgcn, ModelId::Han, ModelId::Magnn] {
        let mut session = ci_builder(model).build().unwrap();
        session.init_weights(config.seed).unwrap();
        let report = session.fit(&config).unwrap();
        assert_eq!(report.epochs.len(), 3);
        let losses: Vec<f64> = report.epochs.iter().map(|e| e.loss).collect();
        assert!(
            report.monotonic_loss(),
            "{model:?}: loss not monotonically decreasing: {losses:?}"
        );
        assert!(report.epochs.iter().all(|e| e.loss.is_finite() && e.backward_dispatches > 0));
    }
}

/// One seeded training run is bit-identical at every thread count —
/// the backward stages keep the serial per-row accumulation order of
/// the forward kernels.
#[test]
fn training_is_bit_identical_across_thread_counts() {
    let config = TrainConfig {
        epochs: 2,
        batch: 8,
        optimizer: OptimizerSpec::sgd(0.05),
        seed: 0x51ED,
        classes: 4,
        fused: true,
    };
    let mut reports = Vec::new();
    let mut finals = Vec::new();
    for threads in [1usize, 4] {
        let mut session = ci_builder(ModelId::Han).threads(threads).build().unwrap();
        session.init_weights(config.seed).unwrap();
        reports.push(session.fit(&config).unwrap());
        finals.push(session.plan().weights.clone());
    }
    for (a, b) in reports[0].epochs.iter().zip(&reports[1].epochs) {
        assert_eq!(a.loss.to_bits(), b.loss.to_bits(), "epoch {} loss", a.epoch);
        assert_eq!(a.accuracy.to_bits(), b.accuracy.to_bits());
        assert_eq!(a.backward_dispatches, b.backward_dispatches);
    }
    assert!(weights_equal(&finals[0], &finals[1]), "weights diverge across thread counts");
}

/// Training composes with `--shards`: the sharded session trains on the
/// same full-graph math, so losses and final weights stay bit-identical
/// between shard counts {1, 2}, and post-training inference still runs
/// through the sharded forward.
#[test]
fn training_is_bit_identical_across_shard_layouts() {
    let config = TrainConfig {
        epochs: 2,
        batch: 8,
        optimizer: OptimizerSpec::sgd(0.05),
        seed: 0x51ED,
        classes: 4,
        fused: true,
    };
    let mut mono = ci_builder(ModelId::Han).build().unwrap();
    mono.init_weights(config.seed).unwrap();
    let report_mono = mono.fit(&config).unwrap();

    let mut sharded = ci_builder(ModelId::Han).partition(PartitionSpec::new(2)).build().unwrap();
    sharded.init_weights(config.seed).unwrap();
    let report_sharded = sharded.fit(&config).unwrap();

    for (a, b) in report_mono.epochs.iter().zip(&report_sharded.epochs) {
        assert_eq!(a.loss.to_bits(), b.loss.to_bits(), "epoch {} loss", a.epoch);
    }
    assert!(weights_equal(&mono.plan().weights, &sharded.plan().weights));
    // the sharded forward serves the trained weights bit-identically
    let (rm, rs) = (mono.run().unwrap(), sharded.run().unwrap());
    assert!(rm.output.allclose(&rs.output, 0.0, 0.0));
}

/// Training through the neighbor sampler: with neighbor-covering fanout
/// and one full-coverage batch, the sampled path reproduces the
/// full-graph epoch loss, and the gradients flow through the sampled
/// plan's sliced embedding tables (R-GCN) without shape errors.
#[test]
fn sampled_training_matches_full_graph_at_full_coverage() {
    let config = TrainConfig {
        epochs: 1,
        batch: usize::MAX,
        optimizer: OptimizerSpec::sgd(0.05),
        seed: 0xAB,
        classes: 4,
        fused: true,
    };
    for model in [ModelId::Han, ModelId::Rgcn] {
        let mut full = ci_builder(model).build().unwrap();
        full.init_weights(config.seed).unwrap();
        let report_full = full.fit(&config).unwrap();

        let mut sampled = ci_builder(model)
            .sampling(SamplingSpec::uniform(usize::MAX, 1))
            .build()
            .unwrap();
        sampled.init_weights(config.seed).unwrap();
        let report_sampled = sampled.fit(&config).unwrap();

        let (a, b) = (report_full.epochs[0].loss, report_sampled.epochs[0].loss);
        assert!(
            (a - b).abs() < 1e-5,
            "{model:?}: sampled epoch loss {b} diverges from full-graph {a}"
        );
    }
}

/// Degenerate train configs are rejected before any work happens.
#[test]
fn fit_rejects_degenerate_configs() {
    let mut session = ci_builder(ModelId::Han).build().unwrap();
    let bad = TrainConfig { epochs: 0, ..Default::default() };
    assert!(session.fit(&bad).is_err());
    let bad = TrainConfig { optimizer: OptimizerSpec::sgd(-1.0), ..Default::default() };
    assert!(session.fit(&bad).is_err());
}

/// Adam also trains: loss after 3 epochs ends below the ln(C) level of
/// an uninformed classifier.
#[test]
fn adam_fit_reduces_loss_below_chance() {
    let config = TrainConfig {
        epochs: 3,
        batch: usize::MAX,
        optimizer: OptimizerSpec::adam(0.01),
        seed: 0xBEEF,
        classes: 4,
        fused: true,
    };
    let mut session = ci_builder(ModelId::Han).build().unwrap();
    session.init_weights(config.seed).unwrap();
    let report = session.fit(&config).unwrap();
    let chance = (4.0f64).ln();
    assert!(
        report.final_loss() < chance,
        "final loss {} not below chance {chance}",
        report.final_loss()
    );
}
