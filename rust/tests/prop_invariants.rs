//! Property-based invariants over the substrate and coordinator, via the
//! mini-proptest framework in `hgnn_char::testutil`.

use hgnn_char::coordinator::lpt_assign;
use hgnn_char::coordinator::schedule::analyze;
use hgnn_char::gpumodel::GpuModel;
use hgnn_char::graph::sparse::Csr;
use hgnn_char::kernels::elementwise::{reduce_grouped_rows, softmax_vec};
use hgnn_char::kernels::sparse_ops::{edge_softmax, sddmm_coo, spmm_csr, SpmmReduce};
use hgnn_char::kernels::{Ctx, KernelCounters, KernelExec, KernelType};
use hgnn_char::profiler::{Profile, StageId};
use hgnn_char::session::SchedulePolicy;
use hgnn_char::tensor::Tensor;
use hgnn_char::testutil::{check, CsrStrategy, Pair, Strategy, TensorStrategy};
use hgnn_char::util::Pcg32;

const CASES: usize = 60;

#[test]
fn prop_csr_transpose_involution() {
    check("transpose∘transpose = id", 11, CASES, &CsrStrategy::default(), |csr| {
        csr.transposed().transposed() == *csr
    });
}

#[test]
fn prop_csr_roundtrip_coo() {
    check("csr -> coo -> csr = id", 12, CASES, &CsrStrategy::default(), |csr| {
        csr.to_coo().to_csr() == *csr
    });
}

#[test]
fn prop_ell_roundtrip_when_k_sufficient() {
    check("ell roundtrip at k = max_degree", 13, CASES, &CsrStrategy::default(), |csr| {
        let k = csr.max_degree().max(1);
        let (ell, trunc) = csr.to_ell(k);
        trunc == 0 && ell.to_csr() == *csr
    });
}

#[test]
fn prop_bool_matmul_identity_neutral() {
    check("A · I = A", 14, CASES, &CsrStrategy::default(), |csr| {
        let id = Csr::identity(csr.n_cols);
        csr.bool_matmul(&id).map(|p| p == *csr).unwrap_or(false)
    });
}

#[test]
fn prop_bool_matmul_associative() {
    // (A·B)·C == A·(B·C) over the boolean semiring — the property that
    // makes metapath composition order-independent.
    struct Triple;
    impl Strategy for Triple {
        type Value = (Csr, Csr, Csr);
        fn generate(&self, rng: &mut Pcg32) -> Self::Value {
            let dims: Vec<usize> = (0..4).map(|_| 1 + rng.gen_range(12)).collect();
            let mk = |rng: &mut Pcg32, r: usize, c: usize| {
                let nnz = rng.gen_range(r * c + 1);
                let edges: Vec<(u32, u32)> = (0..nnz)
                    .map(|_| (rng.gen_range(r) as u32, rng.gen_range(c) as u32))
                    .collect();
                hgnn_char::graph::sparse::Coo::from_edges(r, c, edges)
                    .unwrap()
                    .to_csr()
            };
            (
                mk(rng, dims[0], dims[1]),
                mk(rng, dims[1], dims[2]),
                mk(rng, dims[2], dims[3]),
            )
        }
    }
    check("bool matmul associativity", 24, 40, &Triple, |(a, b, c)| {
        let left = a.bool_matmul(b).unwrap().bool_matmul(c).unwrap();
        let right = a.bool_matmul(&b.bool_matmul(c).unwrap()).unwrap();
        left == right
    });
}

#[test]
fn prop_spmm_linear_in_weights() {
    // spmm(2w) = 2 * spmm(w)
    let strat = CsrStrategy { max_rows: 20, max_cols: 20, max_density: 0.4 };
    check("spmm linearity", 15, 40, &strat, |csr| {
        let mut rng = Pcg32::seeded(csr.nnz() as u64 + 17);
        let x = Tensor::randn(csr.n_cols, 6, 1.0, &mut rng);
        let w: Vec<f32> = (0..csr.nnz()).map(|_| rng.gen_f32()).collect();
        let w2: Vec<f32> = w.iter().map(|v| 2.0 * v).collect();
        let mut ctx = Ctx::default();
        let a = spmm_csr(&mut ctx, csr, &x, Some(&w), SpmmReduce::Sum).unwrap();
        let b = spmm_csr(&mut ctx, csr, &x, Some(&w2), SpmmReduce::Sum).unwrap();
        let mut a2 = a.clone();
        for v in a2.as_mut_slice() {
            *v *= 2.0;
        }
        b.allclose(&a2, 1e-4, 1e-5)
    });
}

#[test]
fn prop_spmm_mean_bounded_by_inputs() {
    // mean aggregation stays inside [min, max] of the gathered features
    let strat = CsrStrategy { max_rows: 16, max_cols: 16, max_density: 0.5 };
    check("mean in range", 16, 40, &strat, |csr| {
        let mut rng = Pcg32::seeded(csr.nnz() as u64 + 3);
        let x = Tensor::randn(csr.n_cols, 4, 1.0, &mut rng);
        let lo = x.as_slice().iter().cloned().fold(f32::INFINITY, f32::min);
        let hi = x.as_slice().iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        let mut ctx = Ctx::default();
        let out = spmm_csr(&mut ctx, csr, &x, None, SpmmReduce::Mean).unwrap();
        (0..csr.n_rows).all(|r| {
            if csr.degree(r) == 0 {
                // isolated nodes aggregate to exactly zero
                return out.row(r).iter().all(|&v| v == 0.0);
            }
            out.row(r).iter().all(|&v| v >= lo - 1e-5 && v <= hi + 1e-5)
        })
    });
}

#[test]
fn prop_edge_softmax_partition_of_unity() {
    check("edge softmax sums to 1 per non-empty row", 17, CASES, &CsrStrategy::default(), |csr| {
        let mut rng = Pcg32::seeded(csr.nnz() as u64 + 29);
        let s_dst: Vec<f32> = (0..csr.n_rows).map(|_| rng.gen_normal()).collect();
        let s_src: Vec<f32> = (0..csr.n_cols).map(|_| rng.gen_normal()).collect();
        let mut ctx = Ctx::default();
        let logits = sddmm_coo(&mut ctx, csr, &s_dst, &s_src, 0.2).unwrap();
        let w = edge_softmax(&mut ctx, csr, &logits).unwrap();
        (0..csr.n_rows).all(|d| {
            let lo = csr.indptr[d] as usize;
            let hi = csr.indptr[d + 1] as usize;
            if lo == hi {
                return true;
            }
            let sum: f32 = w[lo..hi].iter().sum();
            (sum - 1.0).abs() < 1e-4 && w[lo..hi].iter().all(|&v| (0.0..=1.0).contains(&v))
        })
    });
}

#[test]
fn prop_softmax_vec_invariant_to_shift() {
    let strat = TensorStrategy { max_rows: 1, max_cols: 16, scale: 5.0 };
    check("softmax shift invariance", 18, CASES, &strat, |t| {
        let mut ctx = Ctx::default();
        let a = softmax_vec(&mut ctx, t.as_slice());
        let shifted: Vec<f32> = t.as_slice().iter().map(|v| v + 3.5).collect();
        let b = softmax_vec(&mut ctx, &shifted);
        a.iter().zip(&b).all(|(x, y)| (x - y).abs() < 1e-5)
    });
}

#[test]
fn prop_reduce_grouped_matches_manual_sum() {
    let strat = TensorStrategy { max_rows: 12, max_cols: 8, scale: 2.0 };
    check("grouped reduce = manual", 19, CASES, &strat, |t| {
        // duplicate the tensor 3x as groups; reduce must equal 3*t
        let parts = [t, t, t];
        let refs: Vec<&Tensor> = parts.to_vec();
        let mut ctx = Ctx::default();
        let stacked = hgnn_char::kernels::rearrange::concat_rows(&mut ctx, &refs).unwrap();
        let out = reduce_grouped_rows(&mut ctx, &stacked, 3).unwrap();
        let mut expect = (*t).clone();
        for v in expect.as_mut_slice() {
            *v *= 3.0;
        }
        out.allclose(&expect, 1e-5, 1e-5)
    });
}

#[test]
fn prop_lpt_covers_all_and_is_balancedish() {
    struct CostStrategy;
    impl Strategy for CostStrategy {
        type Value = (Vec<f64>, usize);
        fn generate(&self, rng: &mut Pcg32) -> Self::Value {
            let n = 1 + rng.gen_range(20);
            let costs = (0..n).map(|_| 1.0 + rng.gen_f64() * 99.0).collect();
            let workers = 1 + rng.gen_range(6);
            (costs, workers)
        }
    }
    check("lpt assignment", 20, CASES, &CostStrategy, |(costs, workers)| {
        let assign = lpt_assign(costs, *workers);
        if assign.len() != costs.len() {
            return false;
        }
        if !assign.iter().all(|&w| w < *workers) {
            return false;
        }
        // makespan within 2x of the lower bound (LPT guarantees 4/3 + ...)
        let mut load = vec![0.0f64; *workers];
        for (i, &w) in assign.iter().enumerate() {
            load[w] += costs[i];
        }
        let makespan = load.iter().cloned().fold(0.0, f64::max);
        let total: f64 = costs.iter().sum();
        let lb = (total / *workers as f64).max(costs.iter().cloned().fold(0.0, f64::max));
        makespan <= 2.0 * lb + 1e-9
    });
}

#[test]
fn prop_gather_trace_rows_match_csr_indices() {
    check("spmm trace = csr indices", 21, CASES, &CsrStrategy::default(), |csr| {
        let mut rng = Pcg32::seeded(5);
        let x = Tensor::randn(csr.n_cols, 4, 1.0, &mut rng);
        let mut ctx = Ctx::with_traces();
        spmm_csr(&mut ctx, csr, &x, None, SpmmReduce::Sum).unwrap();
        let trace = ctx.events[0].trace.as_ref().unwrap();
        trace.rows == csr.indices
    });
}

#[test]
fn prop_parallel_spmm_bit_identical_to_serial() {
    // the worker pool splits destination rows into blocks but never
    // changes a row's accumulation order — outputs are bitwise equal
    let strat = CsrStrategy { max_rows: 40, max_cols: 40, max_density: 0.3 };
    check("parallel spmm == serial spmm (bitwise)", 31, 40, &strat, |csr| {
        let mut rng = Pcg32::seeded(csr.nnz() as u64 + 5);
        let x = Tensor::randn(csr.n_cols, 8, 1.0, &mut rng);
        let run = |threads: usize| {
            hgnn_char::parallel::with_threads(threads, || {
                let mut ctx = Ctx::default();
                spmm_csr(&mut ctx, csr, &x, None, SpmmReduce::Sum).unwrap()
            })
        };
        let serial = run(1);
        run(2).allclose(&serial, 0.0, 0.0) && run(4).allclose(&serial, 0.0, 0.0)
    });
}

#[test]
fn prop_dropout_is_subset_with_rate() {
    check("dropout subset", 22, CASES, &CsrStrategy::default(), |csr| {
        let mut rng = Pcg32::seeded(csr.n_rows as u64);
        let kept = csr.dropout(0.5, &mut rng);
        if kept.validate().is_err() || kept.nnz() > csr.nnz() {
            return false;
        }
        // every kept edge existed
        (0..kept.n_rows).all(|r| {
            let orig = csr.row(r);
            kept.row(r).iter().all(|c| orig.contains(c))
        })
    });
}

#[test]
fn prop_pair_strategy_spmm_shape_errors_detected() {
    // shape mismatches must error, never panic
    let strat = Pair(CsrStrategy::default(), TensorStrategy::default());
    check("spmm shape safety", 23, CASES, &strat, |(csr, x)| {
        let mut ctx = Ctx::default();
        match spmm_csr(&mut ctx, csr, x, None, SpmmReduce::Sum) {
            Ok(out) => x.rows() == csr.n_cols && out.shape() == (csr.n_rows, x.cols()),
            Err(_) => x.rows() != csr.n_cols,
        }
    });
}

// ---------------------------------------------------------------------------
// ScheduleReport makespan invariants (ISSUE 1 satellite): for arbitrary
// worker-attributed profiles, the modeled parallel makespan never
// exceeds the modeled sequential total and never undercuts the critical
// path through the stage barriers.
// ---------------------------------------------------------------------------

/// Random worker-attributed profile with the paper's stage/type shape:
/// FP is DM-only and NA is TB/EW/DR-only (Fig 3) — the regime the
/// bound-aware-mixing model is defined over. SA kernels are arbitrary.
struct ProfileStrategy;

/// (profile, workers) pair; every NA worker index is < workers.
impl Strategy for ProfileStrategy {
    type Value = (Profile, usize);
    fn generate(&self, rng: &mut Pcg32) -> Self::Value {
        let workers = 1 + rng.gen_range(6);
        let mut p = Profile::default();
        fn push(p: &mut Profile, stage: StageId, worker: usize, rng: &mut Pcg32) {
            let ktype = match stage {
                // Fig 3: FP is pure dense matmul
                StageId::FeatureProjection => KernelType::DenseMatmul,
                // Fig 3: NA is TB + EW (+ the odd DR), never DM
                StageId::NeighborAggregation => match rng.gen_range(3) {
                    0 => KernelType::TopologyBased,
                    1 => KernelType::ElementWise,
                    _ => KernelType::DataRearrange,
                },
                _ => match rng.gen_range(4) {
                    0 => KernelType::DenseMatmul,
                    1 => KernelType::TopologyBased,
                    2 => KernelType::ElementWise,
                    _ => KernelType::DataRearrange,
                },
            };
            let exec = KernelExec {
                name: "k",
                ktype,
                counters: KernelCounters {
                    flops: 1 + rng.gen_range(50_000_000) as u64,
                    bytes_read: 1 + rng.gen_range(80_000_000) as u64,
                    bytes_written: 1 + rng.gen_range(8_000_000) as u64,
                },
                wall_nanos: 1 + rng.gen_range(1_000_000) as u64,
                trace: None,
            };
            p.record(vec![exec], stage, Some("sg"), worker, 0);
        }
        for _ in 0..(1 + rng.gen_range(3)) {
            push(&mut p, StageId::FeatureProjection, 0, rng);
        }
        for _ in 0..(1 + rng.gen_range(8)) {
            let w = rng.gen_range(workers);
            push(&mut p, StageId::NeighborAggregation, w, rng);
        }
        for _ in 0..(1 + rng.gen_range(3)) {
            push(&mut p, StageId::SemanticAggregation, 0, rng);
        }
        p.attach_metrics(&GpuModel::default());
        (p, workers)
    }
}

/// Modeled per-stage makespan: max over workers of that worker's sum.
fn stage_max(p: &Profile, stage: StageId) -> f64 {
    let mut per_worker = std::collections::BTreeMap::new();
    for k in &p.kernels {
        if k.stage == stage {
            let t = k.metrics.as_ref().map(|m| m.time_ns).unwrap_or(0.0);
            *per_worker.entry(k.worker).or_insert(0.0) += t;
        }
    }
    per_worker.values().cloned().fold(0.0, f64::max)
}

#[test]
fn prop_parallel_makespan_bounded_by_serial_total() {
    // parallel makespan <= sequential (serial-sum) total, all policies
    check("makespan <= serial", 31, CASES, &ProfileStrategy, |(p, workers)| {
        let w = *workers;
        SchedulePolicy::all(w).into_iter().all(|policy| {
            let mixing = matches!(policy, SchedulePolicy::BoundAwareMixing { .. });
            let r = analyze(p, w, mixing, policy, &GpuModel::default());
            r.modeled_makespan_ns <= r.modeled_serial_ns * (1.0 + 1e-9) + 1e-6
                && r.speedup >= 1.0 - 1e-9
        })
    });
}

#[test]
fn prop_makespan_at_least_critical_path() {
    // non-mixing schedules: the barriers force
    //   makespan >= FP_max + NA_max + SA_max   (the critical path)
    check("makespan >= critical path", 32, CASES, &ProfileStrategy, |(p, workers)| {
        let w = *workers;
        let critical = stage_max(p, StageId::FeatureProjection)
            + stage_max(p, StageId::NeighborAggregation)
            + stage_max(p, StageId::SemanticAggregation);
        [
            SchedulePolicy::Sequential,
            SchedulePolicy::InterSubgraphParallel { workers: w },
            SchedulePolicy::FusedSubgraph { workers: w },
        ]
        .into_iter()
        .all(|policy| {
            let r = analyze(p, w, false, policy, &GpuModel::default());
            r.modeled_makespan_ns >= critical * (1.0 - 1e-9) - 1e-6
        })
    });
}

/// Random two-type hetero graph from a CSR + its R-GCN plan — the input
/// shape the partition properties quantify over.
fn random_bipartite(
    csr: &Csr,
) -> (hgnn_char::graph::HeteroGraph, hgnn_char::models::ModelPlan) {
    use hgnn_char::graph::HeteroGraphBuilder;
    let mut b = HeteroGraphBuilder::new("prop");
    let a = b.add_node_type("a", 'A', Tensor::full(csr.n_rows, 4, 1.0));
    let s = b.add_node_type("b", 'B', Tensor::full(csr.n_cols, 3, 2.0));
    b.add_relation("B-A", s, a, csr.clone());
    b.add_relation("A-B", a, s, csr.transposed());
    let hg = b.build().unwrap();
    let plan = hgnn_char::models::build_plan(
        hgnn_char::models::ModelId::Rgcn,
        &hg,
        &hgnn_char::models::ModelConfig::default(),
    )
    .unwrap();
    (hg, plan)
}

#[test]
fn prop_partition_is_disjoint_cover_with_foreign_halo() {
    use hgnn_char::partition::{Partition, PartitionSpec};
    check("partition covers, halo foreign", 41, CASES, &CsrStrategy::default(), |csr| {
        let (hg, plan) = random_bipartite(csr);
        [1usize, 2, 3, 5].iter().all(|&k| {
            let part = Partition::build(&hg, &plan, &PartitionSpec::new(k)).unwrap();
            // disjoint cover of every node type
            let cover = hg.node_types().iter().enumerate().all(|(ty, t)| {
                let mut seen = vec![0u8; t.count];
                for shard in &part.shards {
                    for &g in &shard.owned[ty] {
                        seen[g as usize] += 1;
                    }
                }
                seen.iter().all(|&c| c == 1)
            });
            // halo tables reference only foreign-shard nodes, and local
            // spaces are exactly owned ∪ halo, ascending
            let halo_ok = part.shards.iter().enumerate().all(|(s, shard)| {
                shard.halo.iter().enumerate().all(|(ty, list)| {
                    list.iter().all(|&g| part.owner_of(ty, g) != s)
                }) && shard.nodes.iter().enumerate().all(|(ty, list)| {
                    list.windows(2).all(|w| w[0] < w[1])
                        && list.len() == shard.owned[ty].len() + shard.halo[ty].len()
                })
            });
            cover && halo_ok
        })
    });
}

#[test]
fn prop_sharded_forward_bit_identical_on_random_graphs() {
    use hgnn_char::partition::PartitionSpec;
    use hgnn_char::session::Session;
    // fewer cases: each runs four full forwards
    check("sharded == unsharded, bitwise", 42, 12, &CsrStrategy::default(), |csr| {
        let (hg, plan) = random_bipartite(csr);
        let baseline = Session::builder()
            .graph(hg.clone())
            .plan(plan.clone())
            .build()
            .unwrap()
            .run()
            .unwrap();
        [1usize, 2, 4].iter().all(|&k| {
            let run = Session::builder()
                .graph(hg.clone())
                .plan(plan.clone())
                .partition(PartitionSpec::new(k))
                .build()
                .unwrap()
                .run()
                .unwrap();
            run.output.as_slice() == baseline.output.as_slice()
        })
    });
}

#[test]
fn prop_mixing_never_worse_than_plain_parallel() {
    // §5 guideline 1 is an idealized overlap bound: for paper-shaped
    // profiles (FP = DM, NA = memory-bound; what ProfileStrategy
    // generates) it can only shrink the FP+NA window, and SA after the
    // barrier is unchanged. (With DM kernels spread across NA workers
    // the model's single co-scheduled compute stream could exceed the
    // plain per-worker split — that shape does not occur in Fig 3.)
    check("mixing <= plain parallel", 33, CASES, &ProfileStrategy, |(p, workers)| {
        let w = *workers;
        let plain = analyze(
            p,
            w,
            false,
            SchedulePolicy::InterSubgraphParallel { workers: w },
            &GpuModel::default(),
        );
        let mixed = analyze(
            p,
            w,
            true,
            SchedulePolicy::BoundAwareMixing { workers: w },
            &GpuModel::default(),
        );
        let sa = stage_max(p, StageId::SemanticAggregation);
        mixed.modeled_makespan_ns <= plain.modeled_makespan_ns * (1.0 + 1e-9) + 1e-6
            && mixed.modeled_makespan_ns >= sa * (1.0 - 1e-9) - 1e-6
    });
}

// ---------------------------------------------------------------------------
// Dynamic-graph properties (ISSUE 7 satellite): streamed updates converge
// to the same state and outputs regardless of how the stream is batched
// across epoch flips, and a flip evicts only the touched reuse entries.
// ---------------------------------------------------------------------------

/// Random, order-valid update stream for a [`random_bipartite`] graph:
/// edges into both relations, appended nodes of both types, and feature
/// rewrites. Edge updates draw destinations from the *running* counts,
/// so an edge may reference a node appended earlier in the stream —
/// exercising cross-batch references when the stream is split.
/// Duplicate edges are valid no-ops, so no dedup is needed.
fn random_updates(
    hg: &hgnn_char::graph::HeteroGraph,
    rng: &mut Pcg32,
) -> Vec<hgnn_char::dynamic::GraphUpdate> {
    use hgnn_char::dynamic::GraphUpdate;
    let mut counts: Vec<usize> = hg.node_types().iter().map(|t| t.count).collect();
    let dims: Vec<usize> = hg.node_types().iter().map(|t| t.feat_dim).collect();
    (0..8)
        .map(|k| match k % 4 {
            0 | 3 => {
                let rel = rng.gen_range(2);
                let (dt, st) = (hg.relation(rel).dst, hg.relation(rel).src);
                GraphUpdate::AddEdge {
                    relation: rel,
                    dst: rng.gen_range(counts[dt]) as u32,
                    src: rng.gen_range(counts[st]) as u32,
                }
            }
            1 => {
                let ty = rng.gen_range(2);
                counts[ty] += 1;
                GraphUpdate::AddNode { ty, features: vec![rng.gen_f32(); dims[ty]] }
            }
            _ => {
                let ty = rng.gen_range(2);
                GraphUpdate::SetFeatures {
                    ty,
                    node: rng.gen_range(counts[ty]) as u32,
                    features: vec![rng.gen_f32(); dims[ty]],
                }
            }
        })
        .collect()
}

#[test]
fn prop_update_batching_converges_bit_identically() {
    use hgnn_char::dynamic::DynamicSpec;
    use hgnn_char::models::{build_plan, ModelConfig, ModelId};
    use hgnn_char::session::Session;
    // fewer cases: each runs several full forwards + flips
    let strat = CsrStrategy { max_rows: 10, max_cols: 8, max_density: 0.4 };
    check("interleaved flips == one flip == cold", 51, 10, &strat, |csr| {
        let (hg, plan) = random_bipartite(csr);
        if hg.node_types().iter().any(|t| t.count == 0) {
            return true; // degenerate graph: nothing to stream against
        }
        let mut rng = Pcg32::seeded(csr.nnz() as u64 * 31 + csr.n_rows as u64);
        let updates = random_updates(&hg, &mut rng);
        let n = hg.node_type(plan.target).count.min(4) as u32;
        let ids: Vec<u32> = (0..n).collect();

        // same stream, applied as ONE batch vs. a random contiguous split
        // with a flip after every piece (order preserved, so each prefix
        // is valid on its own)
        let mut one = Session::builder()
            .graph(hg.clone())
            .plan(plan.clone())
            .dynamic(DynamicSpec::default())
            .build()
            .unwrap();
        let mut many = Session::builder()
            .graph(hg.clone())
            .plan(plan.clone())
            .dynamic(DynamicSpec::default())
            .build()
            .unwrap();
        // warm both so every flip patches a materialized forward
        let _ = one.run_batch(&ids).unwrap();
        let _ = many.run_batch(&ids).unwrap();

        one.apply_updates(updates.clone()).unwrap();
        one.flip_epoch().unwrap();
        let mut rest = updates;
        while !rest.is_empty() {
            let take = 1 + rng.gen_range(rest.len());
            let batch: Vec<_> = rest.drain(..take).collect();
            many.apply_updates(batch).unwrap();
            many.flip_epoch().unwrap();
        }

        let (sa, sb) = (one.snapshot(), many.snapshot());
        if sa.node_counts != sb.node_counts || sa.edge_counts != sb.edge_counts {
            return false;
        }
        // cold oracle: a fresh session over the fully-applied graph — the
        // plan regenerates prefix-stably, so outputs must be bitwise equal
        let cold_plan =
            build_plan(ModelId::Rgcn, one.graph(), &ModelConfig::default()).unwrap();
        let mut cold =
            Session::builder().graph(one.graph().clone()).plan(cold_plan).build().unwrap();
        let a = one.run_batch(&ids).unwrap();
        let b = many.run_batch(&ids).unwrap();
        let c = cold.run_batch(&ids).unwrap();
        a == b && b == c
    });
}

// ---------------------------------------------------------------------------
// Cluster properties (ISSUE 8 satellite): the wire codec round-trips
// every message type bit-exactly (adversarial f32 payloads included),
// and the coordinator's placement stays a total function onto live
// workers through any seeded sequence of kills, drains and retirements.
// ---------------------------------------------------------------------------

#[test]
fn prop_wire_codec_roundtrips_every_message_type() {
    use hgnn_char::cluster::wire::{decode_frame, encode_frame, Frame};
    use hgnn_char::testutil::MessageStrategy;
    // byte-level round trip: encode → decode → re-encode must reproduce
    // the original buffer exactly. Comparing bytes (not `==`) makes the
    // property hold for NaN / ±0.0 / subnormal payloads too, which is
    // precisely the bit-exactness the cluster-vs-monolith tests rely on.
    check("wire codec roundtrip", 61, 300, &MessageStrategy::default(), |msg| {
        let frame = Frame { seq: 9_000_000_017, from: 3, msg: msg.clone() };
        let bytes = encode_frame(&frame);
        // the length prefix accounts for every byte after itself
        let len = u32::from_le_bytes(bytes[..4].try_into().unwrap()) as usize;
        if len != bytes.len() - 4 {
            return false;
        }
        let decoded = match decode_frame(&bytes[4..]) {
            Ok(f) => f,
            Err(_) => return false,
        };
        decoded.seq == frame.seq
            && decoded.from == frame.from
            && decoded.msg.tag() == frame.msg.tag()
            && decoded.msg.semantic_key() == frame.msg.semantic_key()
            && encode_frame(&decoded) == bytes
    });
}

#[test]
fn prop_placement_total_onto_live_workers_under_failures() {
    use hgnn_char::cluster::{Cluster, ClusterSpec, SimTransport};
    /// (workers, shards, ops): each op is (kind, worker) with kind 0 =
    /// coordinator retire, 1 = drain, 2 = kill + idle detection.
    struct OpsStrategy;
    impl Strategy for OpsStrategy {
        type Value = (usize, usize, Vec<(u8, usize)>);
        fn generate(&self, rng: &mut Pcg32) -> Self::Value {
            let workers = 2 + rng.gen_range(4);
            let shards = 1 + rng.gen_range(10);
            let ops = (0..1 + rng.gen_range(8))
                .map(|_| (rng.gen_range(3) as u8, rng.gen_range(workers)))
                .collect();
            (workers, shards, ops)
        }
    }
    check("placement covers live workers", 62, 40, &OpsStrategy, |(workers, shards, ops)| {
        let spec = ClusterSpec::new(*workers);
        let mut c = Cluster::new(spec, *shards, Box::new(SimTransport::new())).unwrap();
        for &(kind, w) in ops {
            match kind {
                // the coordinator may refuse (last one standing) — that
                // refusal is itself part of the invariant
                0 => drop(c.retire_worker(w)),
                1 => drop(c.drain_worker(w)),
                // a silent death is only observable via heartbeat
                // timeout; 8 idle pumps cross the 200ms threshold
                _ => {
                    c.kill_worker(w);
                    c.run_idle(8).unwrap();
                }
            }
            // after every step: placement is total over the shards and
            // every owner is un-retired; and whenever any live worker
            // remains, every owner is live (a dead owner may persist
            // only in the nowhere-to-re-place endgame)
            let active = c.active_workers();
            let live = c.live_workers();
            let total = c.placement().len() == *shards;
            let unretired = c.placement().iter().all(|&o| active.contains(&o));
            let on_live =
                live.is_empty() || c.placement().iter().all(|&o| live.contains(&o));
            if !(total && unretired && on_live && !active.is_empty()) {
                return false;
            }
        }
        true
    });
}

#[test]
fn prop_untouched_reuse_entries_survive_a_flip() {
    use hgnn_char::dynamic::{DynamicSpec, GraphUpdate};
    use hgnn_char::reuse::ReuseSpec;
    use hgnn_char::sampler::SamplingSpec;
    use hgnn_char::session::Session;
    // the doc promise of `reuse/mod.rs`: a flip performs *targeted*
    // eviction — no generation bump, untouched entries keep serving hits
    let strat = CsrStrategy { max_rows: 10, max_cols: 8, max_density: 0.4 };
    check("flip evicts only touched reuse entries", 52, 10, &strat, |csr| {
        let (hg, plan) = random_bipartite(csr);
        if hg.node_types().iter().any(|t| t.count == 0) {
            return true;
        }
        // a genuinely-new edge in the relation aggregating INTO the
        // target type, so the warm cache holds the key the flip evicts
        let rel = (0..hg.relations().len())
            .find(|&r| hg.relation(r).dst == plan.target)
            .unwrap();
        let adj = &hg.relation(rel).adj;
        let Some((dst, src)) = (0..adj.n_rows).find_map(|d| {
            (0..adj.n_cols as u32).find(|s| !adj.row(d).contains(s)).map(|s| (d as u32, s))
        }) else {
            return true; // relation already complete: nothing new to insert
        };

        let ids: Vec<u32> = (0..hg.node_type(plan.target).count as u32).collect();
        let mut live = Session::builder()
            .graph(hg.clone())
            .plan(plan.clone())
            .sampling(SamplingSpec::uniform(usize::MAX, 1))
            .reuse(ReuseSpec::rows(1 << 12))
            .dynamic(DynamicSpec::default())
            .build()
            .unwrap();
        let _ = live.run_batch(&ids).unwrap();
        let s0 = live.reuse_stats().unwrap();

        live.apply_updates(vec![GraphUpdate::AddEdge { relation: rel, dst, src }]).unwrap();
        live.flip_epoch().unwrap();
        let s1 = live.reuse_stats().unwrap();
        // targeted eviction, never a generation bump
        if s1.invalidations != s0.invalidations || s1.targeted_evictions <= s0.targeted_evictions
        {
            return false;
        }

        // untouched entries survive: replaying the warm batch still hits
        let again = live.run_batch(&ids).unwrap();
        let s2 = live.reuse_stats().unwrap();
        if s2.proj_hits + s2.agg_hits <= s1.proj_hits + s1.agg_hits {
            return false;
        }
        // and the surviving entries serve rows bitwise equal to a cold
        // session over the applied graph (same plan: no growth here)
        let mut cold = Session::builder()
            .graph(live.graph().clone())
            .plan(live.plan().clone())
            .sampling(SamplingSpec::uniform(usize::MAX, 1))
            .reuse(ReuseSpec::rows(1 << 12))
            .build()
            .unwrap();
        again == cold.run_batch(&ids).unwrap()
    });
}
